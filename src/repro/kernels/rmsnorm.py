"""Pallas TPU kernel: row-wise RMSNorm.

Tiles rows of the (tokens, d_model) activation through VMEM; each program
normalizes a (block_rows, d) tile in one pass (f32 accumulation, cast back).
d_model must be lane-aligned (all assigned configs are multiples of 128; the
wrapper pads the row dim only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x / jnp.sqrt(var + eps) * s_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6,
            block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """x: (..., d); scale: (d,). Returns same shape/dtype as x."""
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    blocks = max(1, -(-rows // block_rows))
    padded = blocks * block_rows
    if padded != rows:
        xf = jnp.pad(xf, ((0, padded - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, d), x.dtype),
        interpret=interpret,
    )(xf, scale.reshape(1, d))
    return out[:rows].reshape(shape)
