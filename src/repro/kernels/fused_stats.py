"""Pallas TPU kernel: fused norm-test statistics — Σ(x−y)² AND Σy² in ONE
read of the two operands (DESIGN §9).

The DDP-/FSDP-Norm statistic needs both ‖g_j − g‖² (per-worker squared
deviation) and ‖g‖² (the denominator of eq. 5's test) every step.  Computed
separately (`sqdiff_norm` + a `tree_sqnorm`) that is two full HBM passes
over the mean gradient; here each (block_rows, 128) tile of x and y is
streamed through VMEM once and BOTH partial sums are accumulated in f32 —
one read of each operand, no extra passes, no intermediate writes.

Grid: 1-D over row-blocks; each program writes one f32 partial per
statistic; the wrapper sums the partials (trivially small).  Zero padding is
harmless: it contributes 0 to both sums.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import LANE, pad_to_blocks, resolve_interpret

DEFAULT_BLOCK_ROWS = 256     # 256×128 f32 tile = 128 KiB/operand in VMEM


def _kernel(x_ref, y_ref, diff_ref, ysq_ref):
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    d = x - y
    diff_ref[0, 0] = jnp.sum(d * d)
    ysq_ref[0, 0] = jnp.sum(y * y)


def fused_stats(x, y, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool | None = None):
    """(Σ(x−y)², Σy²) over equal-shape tensors, f32, one read of each."""
    assert x.shape == y.shape, (x.shape, y.shape)
    ip = resolve_interpret(interpret)
    xf, blocks = pad_to_blocks(x.reshape(-1), block_rows)
    yf, _ = pad_to_blocks(y.reshape(-1), block_rows)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    part = pl.BlockSpec((1, 1), lambda i: (i, 0))
    diff, ysq = pl.pallas_call(
        _kernel,
        grid=(blocks,),
        in_specs=[spec, spec],
        out_specs=[part, part],
        out_shape=[jax.ShapeDtypeStruct((blocks, 1), jnp.float32),
                   jax.ShapeDtypeStruct((blocks, 1), jnp.float32)],
        interpret=ip,
    )(xf, yf)
    return jnp.sum(diff), jnp.sum(ysq)
