"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sqdiff_norm_ref(x, y):
    """Σ (x − y)² in f32 (the norm-test reduction)."""
    d = x.astype(jnp.float32) - y.astype(jnp.float32)
    return jnp.sum(d * d)


def sqnorm_ref(x):
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def fused_stats_ref(x, y):
    """(Σ(x−y)², Σy²) in f32 — the single-pass norm-test statistics pair."""
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    d = x32 - y32
    return jnp.sum(d * d), jnp.sum(y32 * y32)


def adamw_stats_ref(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay,
                    c1, c2, clip_scale=1.0):
    """Flat AdamW with clip scale folded in + pre-clip Σg² byproduct."""
    g32 = g.astype(jnp.float32)
    gsq = jnp.sum(g32 * g32)
    p2, m2, v2 = adamw_ref(p, g32 * clip_scale, m, v, lr=lr, beta1=beta1,
                           beta2=beta2, eps=eps, weight_decay=weight_decay,
                           c1=c1, c2=c2)
    return p2, m2, v2, gsq


def adamw_ref(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay, c1, c2):
    """One AdamW update on a flat tensor (bias-corrected, decoupled decay)."""
    g32 = g.astype(jnp.float32)
    m = beta1 * m + (1 - beta1) * g32
    v = beta2 * v + (1 - beta2) * jnp.square(g32)
    mhat = m / c1
    vhat = v / c2
    p32 = p.astype(jnp.float32)
    p32 = (1.0 - lr * weight_decay) * p32 - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p32.astype(p.dtype), m, v


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 / jnp.sqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q,k,v: (b, t, h, d) (same head count — GQA expansion happens in the
    wrapper).  Returns (b, t, h, d)."""
    b, t, h, d = q.shape
    s = k.shape[1]
    logits = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -2.0e38)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", p, v.astype(jnp.float32)).astype(q.dtype)
