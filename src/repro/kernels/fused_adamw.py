"""Pallas TPU kernel: fused AdamW update (Algorithm 1's optimizer block).

One pass over (p, g, m, v) tiles in VMEM producing (p', m', v') — instead of
the ~10 separate elementwise HLO ops (each an HBM round-trip) XLA emits for
the unfused update.  Scalar step state (lr and the bias corrections c1, c2,
which change every step) arrives as a (1, 8) f32 operand broadcast to every
grid step; the static hyperparameters are closure constants.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def _kernel(scalars_ref, p_ref, g_ref, m_ref, v_ref,
            p_out, m_out, v_out, *, beta1, beta2, eps, weight_decay):
    lr = scalars_ref[0, 0]
    c1 = scalars_ref[0, 1]
    c2 = scalars_ref[0, 2]
    g = g_ref[...].astype(jnp.float32)
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mhat = m / c1
    vhat = v / c2
    p = p_ref[...].astype(jnp.float32)
    p = (1.0 - lr * weight_decay) * p - lr * mhat / (jnp.sqrt(vhat) + eps)
    p_out[...] = p.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def _pad_2d(flat, block_rows):
    n = flat.shape[0]
    per_block = block_rows * LANE
    blocks = max(1, -(-n // per_block))
    padded = blocks * per_block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(blocks * block_rows, LANE), blocks


def fused_adamw(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay, c1, c2,
                block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """AdamW update on one tensor; returns (p', m', v') with p's shape/dtype."""
    shape, n = p.shape, p.size
    pf, blocks = _pad_2d(p.reshape(-1), block_rows)
    gf, _ = _pad_2d(g.reshape(-1), block_rows)
    mf, _ = _pad_2d(m.reshape(-1).astype(jnp.float32), block_rows)
    vf, _ = _pad_2d(v.reshape(-1).astype(jnp.float32), block_rows)
    scalars = jnp.stack([jnp.asarray(lr, jnp.float32),
                         jnp.asarray(c1, jnp.float32),
                         jnp.asarray(c2, jnp.float32),
                         jnp.zeros((), jnp.float32)]).reshape(1, 4)

    kernel = functools.partial(_kernel, beta1=beta1, beta2=beta2, eps=eps,
                               weight_decay=weight_decay)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    p2, m2, v2 = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, 4), lambda i: (0, 0)), spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(pf.shape, p.dtype),
            jax.ShapeDtypeStruct(mf.shape, jnp.float32),
            jax.ShapeDtypeStruct(vf.shape, jnp.float32),
        ],
        interpret=interpret,
    )(scalars, pf, gf, mf, vf)
    unpad = lambda a: a.reshape(-1)[:n].reshape(shape)
    return unpad(p2), unpad(m2), unpad(v2)
