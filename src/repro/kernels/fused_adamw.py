"""Pallas TPU kernel: fused AdamW update (Algorithm 1's optimizer block).

One pass over (p, g, m, v) tiles in VMEM producing (p', m', v') — instead of
the ~10 separate elementwise HLO ops (each an HBM round-trip) XLA emits for
the unfused update.  Scalar step state (lr, the bias corrections c1/c2, and
the global-norm clip scale, all of which change every step) arrives as a
(1, 4) f32 operand broadcast to every grid step; the static hyperparameters
are closure constants.

Two entry points:

* `fused_adamw`       — the original per-tensor update (p', m', v').
* `fused_adamw_stats` — the flat-buffer path (DESIGN §9): same update over
  one dtype-homogeneous buffer, consuming a traced `clip_scale` and emitting
  **Σg² of the raw gradient as a kernel byproduct** (one f32 partial per
  block), so the ACCUM-NORM statistic and the `grad_norm` metric cost zero
  extra passes over gradient-sized data.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import LANE, pad_to_blocks, resolve_interpret

DEFAULT_BLOCK_ROWS = 256


def _update(g, p_ref, m_ref, v_ref, scalars_ref, *, beta1, beta2, eps,
            weight_decay):
    lr = scalars_ref[0, 0]
    c1 = scalars_ref[0, 1]
    c2 = scalars_ref[0, 2]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mhat = m / c1
    vhat = v / c2
    p = p_ref[...].astype(jnp.float32)
    p = (1.0 - lr * weight_decay) * p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p, m, v


def _kernel(scalars_ref, p_ref, g_ref, m_ref, v_ref,
            p_out, m_out, v_out, *, beta1, beta2, eps, weight_decay):
    g = g_ref[...].astype(jnp.float32)
    p, m, v = _update(g, p_ref, m_ref, v_ref, scalars_ref, beta1=beta1,
                      beta2=beta2, eps=eps, weight_decay=weight_decay)
    p_out[...] = p.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def _stats_kernel(scalars_ref, p_ref, g_ref, m_ref, v_ref,
                  p_out, m_out, v_out, gsq_out, *, beta1, beta2, eps,
                  weight_decay):
    g_raw = g_ref[...].astype(jnp.float32)
    gsq_out[0, 0] = jnp.sum(g_raw * g_raw)        # byproduct: pre-clip Σg²
    g = g_raw * scalars_ref[0, 3]                  # global-norm clip scale
    p, m, v = _update(g, p_ref, m_ref, v_ref, scalars_ref, beta1=beta1,
                      beta2=beta2, eps=eps, weight_decay=weight_decay)
    p_out[...] = p.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def _scalars(lr, c1, c2, clip_scale=1.0):
    return jnp.stack([jnp.asarray(lr, jnp.float32),
                      jnp.asarray(c1, jnp.float32),
                      jnp.asarray(c2, jnp.float32),
                      jnp.asarray(clip_scale, jnp.float32)]).reshape(1, 4)


def fused_adamw(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay, c1, c2,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool | None = None):
    """AdamW update on one tensor; returns (p', m', v') with p's shape/dtype."""
    ip = resolve_interpret(interpret)
    shape, n = p.shape, p.size
    pf, blocks = pad_to_blocks(p.reshape(-1), block_rows)
    gf, _ = pad_to_blocks(g.reshape(-1), block_rows)
    mf, _ = pad_to_blocks(m.reshape(-1).astype(jnp.float32), block_rows)
    vf, _ = pad_to_blocks(v.reshape(-1).astype(jnp.float32), block_rows)

    kernel = functools.partial(_kernel, beta1=beta1, beta2=beta2, eps=eps,
                               weight_decay=weight_decay)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    p2, m2, v2 = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, 4), lambda i: (0, 0)), spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(pf.shape, p.dtype),
            jax.ShapeDtypeStruct(mf.shape, jnp.float32),
            jax.ShapeDtypeStruct(vf.shape, jnp.float32),
        ],
        interpret=ip,
    )(_scalars(lr, c1, c2), pf, gf, mf, vf)
    unpad = lambda a: a.reshape(-1)[:n].reshape(shape)
    return unpad(p2), unpad(m2), unpad(v2)


def fused_adamw_stats(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay,
                      c1, c2, clip_scale=1.0,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool | None = None):
    """Flat-buffer AdamW: one launch over one dtype-homogeneous buffer.

    `clip_scale` (traced f32) is folded into the gradient inside the kernel;
    returns (p', m', v', Σg²) where Σg² is of the RAW (pre-clip) gradient —
    zero padding contributes nothing to it."""
    ip = resolve_interpret(interpret)
    shape, n = p.shape, p.size
    pf, blocks = pad_to_blocks(p.reshape(-1), block_rows)
    gf, _ = pad_to_blocks(g.reshape(-1), block_rows)
    mf, _ = pad_to_blocks(m.reshape(-1).astype(jnp.float32), block_rows)
    vf, _ = pad_to_blocks(v.reshape(-1).astype(jnp.float32), block_rows)

    kernel = functools.partial(_stats_kernel, beta1=beta1, beta2=beta2,
                               eps=eps, weight_decay=weight_decay)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    part = pl.BlockSpec((1, 1), lambda i: (i, 0))
    p2, m2, v2, gsq = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, 4), lambda i: (0, 0)), spec, spec, spec, spec],
        out_specs=[spec, spec, spec, part],
        out_shape=[
            jax.ShapeDtypeStruct(pf.shape, p.dtype),
            jax.ShapeDtypeStruct(mf.shape, jnp.float32),
            jax.ShapeDtypeStruct(vf.shape, jnp.float32),
            jax.ShapeDtypeStruct((blocks, 1), jnp.float32),
        ],
        interpret=ip,
    )(_scalars(lr, c1, c2, clip_scale), pf, gf, mf, vf)
    unpad = lambda a: a.reshape(-1)[:n].reshape(shape)
    return unpad(p2), unpad(m2), unpad(v2), jnp.sum(gsq)
