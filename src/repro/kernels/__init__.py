# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Shared Pallas plumbing lives here so every kernel module resolves the
# execution mode the same way instead of hard-coding `interpret=True`:
#
# * `resolve_interpret(flag)` — explicit flag wins; else the
#   `REPRO_PALLAS_INTERPRET` env var (0/1); else auto-detect once per
#   process (compiled on TPU, interpreted everywhere else).
# * `pad_to_blocks(flat, block_rows)` — the common (rows, LANE) padding
#   used by the 1-D-grid reduction/update kernels.

from __future__ import annotations

import functools
import os

LANE = 128


@functools.lru_cache(maxsize=None)
def _backend_is_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def resolve_interpret(flag: bool | None = None) -> bool:
    """Pallas execution mode: explicit flag > env override > backend.

    `REPRO_PALLAS_INTERPRET=1` forces interpret mode everywhere (debugging);
    `=0` forces compiled Pallas even off-TPU (will fail on backends without
    Mosaic — use only on TPU-like targets).  Unset: compiled on TPU,
    interpreted elsewhere (this container is CPU-only; interpret mode is the
    correctness path, validated against ref.py).
    """
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env:
        return env not in ("0", "false", "no")
    return not _backend_is_tpu()


def pad_to_blocks(flat, block_rows: int):
    """Zero-pad a 1-D array to whole (block_rows, LANE) tiles; returns the
    (blocks*block_rows, LANE) view and the block count."""
    import jax.numpy as jnp
    n = flat.shape[0]
    per_block = block_rows * LANE
    blocks = max(1, -(-n // per_block))
    padded = blocks * per_block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(blocks * block_rows, LANE), blocks
