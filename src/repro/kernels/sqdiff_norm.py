"""Pallas TPU kernel: fused Σ(x−y)² reduction — the norm-test hot spot.

The paper's DDP-/FSDP-Norm evaluates ‖g_j − g‖² over the whole gradient every
step.  Naively that materializes the difference tensor (one extra gradient-
sized HBM round-trip).  This kernel streams x and y through VMEM in
(8k, 128)-element tiles and accumulates the squared difference in f32 without
writing the intermediate — one read of each operand, no extra writes.

Grid: 1-D over row-blocks; each program writes one f32 partial; the wrapper
sums the partials (a trivially small reduction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import LANE, pad_to_blocks, resolve_interpret

DEFAULT_BLOCK_ROWS = 256     # 256×128 f32 tile = 128 KiB/operand in VMEM


def _kernel(x_ref, y_ref, o_ref):
    d = x_ref[...].astype(jnp.float32) - y_ref[...].astype(jnp.float32)
    o_ref[0, 0] = jnp.sum(d * d)


def sqdiff_norm(x, y, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool | None = None):
    """Σ(x−y)² over arbitrarily-shaped equal-shape tensors, f32 result."""
    assert x.shape == y.shape, (x.shape, y.shape)
    ip = resolve_interpret(interpret)
    xf, blocks = pad_to_blocks(x.reshape(-1), block_rows)
    yf, _ = pad_to_blocks(y.reshape(-1), block_rows)
    partials = pl.pallas_call(
        _kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, 1), jnp.float32),
        interpret=ip,
    )(xf, yf)
    return jnp.sum(partials)
