"""Pallas TPU kernel: blockwise flash attention (causal / sliding-window /
logit-softcap) — the TPU-native replacement for the jnp chunked attention in
`repro.models.attention`.

Grid: (batch·heads, q_blocks, kv_blocks), sequential minor-to-major on TPU, so
the kv_block axis is innermost and the online-softmax state (running max m,
denominator l, accumulator acc) lives in VMEM scratch across kv iterations:

    @ kv_block == 0:        init m = -inf, l = 0, acc = 0
    each kv_block:          s = q·kᵀ (softcap / mask) ; online-softmax update
    @ kv_block == last:     out = acc / l

Causality/window skip whole blocks via `pl.when` (no wasted MXU work on fully
masked blocks — this is the structural win over the jnp scan, which computes
every (q,kv) pair).  GQA: the kv index map divides the head index, so kv
blocks are read once per q-head group without materializing repeats.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, softcap, causal, window, block_q, block_kv, seq_len):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * block_q
    kv_start = kb * block_kv

    # block-level relevance: any (i,j) with j <= i and j > i - window?
    run = True
    if causal:
        run = jnp.logical_and(True, kv_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, kv_start + block_kv - 1 > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (block_q, d)
        k = k_ref[0].astype(jnp.float32)              # (block_kv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kb == nkv - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_kv: int = 256, interpret: bool = True):
    """q: (b, t, h, d); k/v: (b, s, kv_heads, d) with h % kv_heads == 0.
    Returns (b, t, h, d).  Softmax scale is 1/sqrt(d)."""
    b, t, h, d = q.shape
    s = k.shape[1]
    kvh = k.shape[2]
    assert h % kvh == 0
    group = h // kvh
    block_q = min(block_q, t)
    block_kv = min(block_kv, s)
    assert t % block_q == 0
    pad_s = -(-s // block_kv) * block_kv
    if pad_s != s:
        k = jnp.pad(k, ((0, 0), (0, pad_s - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s - s), (0, 0), (0, 0)))

    # (b*h, t, d) layout; kv stays (b*kvh, s, d) and the index map folds GQA
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, pad_s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, pad_s, d)

    grid = (b * h, t // block_q, pad_s // block_kv)

    def q_map(bh, qb, kb):
        return (bh, qb, 0)

    def kv_map(bh, qb, kb):
        return ((bh // h) * kvh + (bh % h) // group, kb, 0)

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(d), softcap=softcap, causal=causal,
        window=window, block_q=block_q, block_kv=block_kv, seq_len=s)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)
