"""jit'd public wrappers for the Pallas kernels.

`interpret` resolves through `repro.kernels.resolve_interpret`: explicit
flag > `REPRO_PALLAS_INTERPRET` env override > backend auto-detect (compiled
on TPU, interpreted elsewhere — this container is CPU-only; the kernels
target TPU and are validated against ref.py in interpret mode).

The `*_flat` entry points at the bottom are the DESIGN §9 hot-path dispatch:
compiled Pallas on TPU, the fused-jnp reference otherwise (interpret-mode
Pallas is a correctness tool, far too slow for the per-step tail).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import _backend_is_tpu, resolve_interpret as _default_resolve
from repro.kernels import ref
from repro.kernels.sqdiff_norm import sqdiff_norm as _sqdiff_norm
from repro.kernels.fused_adamw import (
    fused_adamw as _fused_adamw, fused_adamw_stats as _fused_adamw_stats)
from repro.kernels.fused_stats import fused_stats as _fused_stats
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.flash_attention import flash_attention as _flash_attention


def _default_interpret() -> bool:
    return _default_resolve(None)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sqdiff_norm(x, y, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _sqdiff_norm(x, y, interpret=ip)


def sqdiff_norm_tree(tree_a, tree_b, interpret: bool | None = None):
    """Fused Σ‖a−b‖² over a whole gradient pytree (norm-test statistic)."""
    ip = _default_interpret() if interpret is None else interpret
    total = jnp.zeros((), jnp.float32)
    for a, b in zip(jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)):
        total += _sqdiff_norm(a, b, interpret=ip)
    return total


@functools.partial(jax.jit, static_argnames=(
    "beta1", "beta2", "eps", "weight_decay", "interpret"))
def fused_adamw(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay, c1, c2,
                interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _fused_adamw(p, g, m, v, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                        weight_decay=weight_decay, c1=c1, c2=c2, interpret=ip)


def fused_adamw_tree(params, grads, m, v, *, lr, beta1, beta2, eps,
                     weight_decay, c1, c2, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = jax.tree.leaves(grads)
    leaves_m = jax.tree.leaves(m)
    leaves_v = jax.tree.leaves(v)
    new_p, new_m, new_v = [], [], []
    for p_, g_, m_, v_ in zip(leaves_p, leaves_g, leaves_m, leaves_v):
        a, b, c = _fused_adamw(p_, g_, m_, v_, lr=lr, beta1=beta1, beta2=beta2,
                               eps=eps, weight_decay=weight_decay, c1=c1,
                               c2=c2, interpret=ip)
        new_p.append(a); new_m.append(b); new_v.append(c)
    unf = treedef.unflatten
    return unf(new_p), unf(new_m), unf(new_v)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_stats(x, y, interpret: bool | None = None):
    """(Σ(x−y)², Σy²) in one read of each operand (norm-test statistics)."""
    ip = _default_interpret() if interpret is None else interpret
    return _fused_stats(x, y, interpret=ip)


@functools.partial(jax.jit, static_argnames=(
    "beta1", "beta2", "eps", "weight_decay", "interpret"))
def fused_adamw_stats(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay,
                      c1, c2, clip_scale=1.0, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _fused_adamw_stats(p, g, m, v, lr=lr, beta1=beta1, beta2=beta2,
                              eps=eps, weight_decay=weight_decay, c1=c1,
                              c2=c2, clip_scale=clip_scale, interpret=ip)


# ------------------------------------------------ flat hot-path dispatch ----
# Traced inside the train steps (no jit here — the callers are jitted).
# Compiled Pallas on TPU; fused-jnp reference elsewhere.  NOT governed by
# REPRO_PALLAS_INTERPRET: interpret-mode Pallas is for validating kernels,
# not for running the per-step tail.

def stats_flat(x, y):
    """Backend-dispatched single-pass (Σ(x−y)², Σy²) over flat buffers.

    The Pallas grid is sized from the operand actually passed in — inside a
    shard_map manual region that is the worker's LOCAL bucket shard, so a
    J-way-sharded bucket costs 1/J of the launch grid per worker (zero
    shard-padding contributes nothing to either sum)."""
    if _backend_is_tpu():
        return _fused_stats(x, y, interpret=False)
    return ref.fused_stats_ref(x, y)


def adamw_flat(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay, c1, c2,
               clip_scale=1.0):
    """Backend-dispatched flat-buffer AdamW; returns (p', m', v', Σg²_raw).

    Like `stats_flat`, the grid covers whatever buffer arrives: under the
    sharded-bucket FSDP-Norm step each worker updates only its 1/J bucket
    shard, so per-worker update flops and moment traffic drop by J."""
    if _backend_is_tpu():
        return _fused_adamw_stats(p, g, m, v, lr=lr, beta1=beta1, beta2=beta2,
                                  eps=eps, weight_decay=weight_decay, c1=c1,
                                  c2=c2, clip_scale=clip_scale,
                                  interpret=False)
    return ref.adamw_stats_ref(p, g, m, v, lr=lr, beta1=beta1, beta2=beta2,
                               eps=eps, weight_decay=weight_decay, c1=c1,
                               c2=c2, clip_scale=clip_scale)


def flat_dispatch_info() -> dict:
    """Which implementation the DESIGN §9 flat hot-path tail dispatches to
    on this process's backend.  Recorded in the `repro.analysis` report's
    `checked` section: a clean static-analysis run thereby documents WHICH
    backend's step graphs it certified (the compiled-Pallas TPU tail and
    the fused-jnp CPU tail lower different equations)."""
    return {
        "backend": jax.default_backend(),
        "flat_tail": "pallas-compiled" if _backend_is_tpu() else
                     "jnp-reference",
        "pallas_interpret_default": bool(_default_interpret()),
    }


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _rmsnorm(x, scale, eps=eps, interpret=ip)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=256, block_kv=256, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, block_q=block_q,
                            block_kv=block_kv, interpret=ip)
