"""jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True off-TPU (this container is CPU-only; the kernels
target TPU and are validated against ref.py in interpret mode) and False on a
real TPU backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.sqdiff_norm import sqdiff_norm as _sqdiff_norm
from repro.kernels.fused_adamw import fused_adamw as _fused_adamw
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.flash_attention import flash_attention as _flash_attention


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def sqdiff_norm(x, y, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _sqdiff_norm(x, y, interpret=ip)


def sqdiff_norm_tree(tree_a, tree_b, interpret: bool | None = None):
    """Fused Σ‖a−b‖² over a whole gradient pytree (norm-test statistic)."""
    ip = _default_interpret() if interpret is None else interpret
    total = jnp.zeros((), jnp.float32)
    for a, b in zip(jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)):
        total += _sqdiff_norm(a, b, interpret=ip)
    return total


@functools.partial(jax.jit, static_argnames=(
    "beta1", "beta2", "eps", "weight_decay", "interpret"))
def fused_adamw(p, g, m, v, *, lr, beta1, beta2, eps, weight_decay, c1, c2,
                interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _fused_adamw(p, g, m, v, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                        weight_decay=weight_decay, c1=c1, c2=c2, interpret=ip)


def fused_adamw_tree(params, grads, m, v, *, lr, beta1, beta2, eps,
                     weight_decay, c1, c2, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = jax.tree.leaves(grads)
    leaves_m = jax.tree.leaves(m)
    leaves_v = jax.tree.leaves(v)
    new_p, new_m, new_v = [], [], []
    for p_, g_, m_, v_ in zip(leaves_p, leaves_g, leaves_m, leaves_v):
        a, b, c = _fused_adamw(p_, g_, m_, v_, lr=lr, beta1=beta1, beta2=beta2,
                               eps=eps, weight_decay=weight_decay, c1=c1,
                               c2=c2, interpret=ip)
        new_p.append(a); new_m.append(b); new_v.append(c)
    unf = treedef.unflatten
    return unf(new_p), unf(new_m), unf(new_v)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _rmsnorm(x, scale, eps=eps, interpret=ip)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=256, block_kv=256, interpret: bool | None = None):
    ip = _default_interpret() if interpret is None else interpret
    return _flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, block_q=block_q,
                            block_kv=block_kv, interpret=ip)
