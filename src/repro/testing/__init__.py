"""Test-support subsystems that ship with the library (not under tests/):
the deterministic fault-injection harness lives here because its injection
points are compiled into the production modules (engine, coordination,
checkpoint store, train loop) and must be importable from any process —
including the subprocess workers the chaos suite kills."""

from repro.testing.faults import (       # noqa: F401
    FaultInjector, FaultRule, InjectedFault, fault_point, inject)
