"""Deterministic fault injection for chaos testing (DESIGN §12).

The recovery machinery this repo grew — crash-atomic checkpoints + resume,
coordinator liveness, warmup-compile retry — is only trustworthy if its
failure paths execute on every PR.  Real faults (a SIGKILLed rank, a torn
checkpoint write, a flaky XLA compile) are rare and nondeterministic; this
harness makes them *scheduled*: production modules call
``fault_point("site")`` at their failure-relevant spots, and a configured
`FaultInjector` decides — **deterministically, by per-site invocation
count** — whether that particular call raises, sleeps, truncates a file, or
kills the process.

Sites compiled into the codebase today:

* ``train.step``            — top of each training-loop iteration (the
                              invocation index IS the 1-based step number);
                              ``die`` here is the kill-at-step-k test.
* ``ckpt.save.before_commit`` — after a checkpoint's temp files are written,
                              before either atomic rename: ``die`` leaves
                              only ``*.tmp*`` litter, which the next save
                              must clean and `latest_step` must never see.
* ``ckpt.saved``            — after a checkpoint commit, with ``path=`` the
                              npz: ``truncate`` produces the torn-file
                              corpus for the loud-restore tests.
* ``engine.compile``        — foreground step build in `RungCache.lookup`.
* ``engine.warmup_compile`` — each ATTEMPT of a background AOT warmup
                              (fires again on retry, so ``count`` selects
                              transient-vs-permanent failures).
* ``coord.barrier``         — barrier entry in `FileCoordinator` (``delay``
                              simulates a straggler, ``die`` a rank lost at
                              the rendezvous).

Configuration is programmatic (``with faults.inject(FaultRule(...)):`` for
in-process tests) or via the ``REPRO_FAULTS`` environment variable — a JSON
rule list parsed at import, which is how the chaos suite arms subprocess /
CLI workers:

    REPRO_FAULTS='[{"site": "train.step", "at": 7, "action": "die"}]'

Determinism contract: no wall clock, no RNG — a rule fires iff the site's
invocation counter lands in ``[at, at + count)``, so two runs of the same
deterministic program hit identical faults at identical points.  When no
injector is active, ``fault_point`` is a single attribute load + None check.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import signal
import threading
import time

_ACTIONS = ("raise", "delay", "die", "truncate")


class InjectedFault(RuntimeError):
    """Raised by ``action="raise"`` rules at their site."""


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One scheduled fault: fire at invocations [at, at+count) of `site`."""
    site: str
    action: str = "raise"     # raise | delay | die | truncate
    at: int = 1               # first firing invocation (1-based)
    count: int = 1            # how many consecutive invocations fire
    delay_s: float = 0.0      # sleep length for action="delay"
    keep_bytes: int = 0       # truncated size for action="truncate"
    message: str = "injected fault"

    def __post_init__(self):
        if self.action not in _ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r} "
                             f"(expected one of {_ACTIONS})")
        if self.at < 1 or self.count < 1:
            raise ValueError(f"fault window must be at>=1, count>=1: {self}")


class FaultInjector:
    """Deterministic per-site invocation counters driving a rule list.

    Thread-safe: counters update under a lock (warmup-pool threads hit
    engine sites concurrently with the training thread).  `fired` exposes
    the (site, invocation, action) log so tests can assert exactly-once
    firing instead of inferring it from side effects."""

    def __init__(self, rules):
        self.rules = tuple(r if isinstance(r, FaultRule) else FaultRule(**r)
                           for r in rules)
        self._counts: dict[str, int] = {}
        self._log: list[tuple[str, int, str]] = []
        self._lock = threading.Lock()

    @classmethod
    def from_env(cls, var: str = "REPRO_FAULTS"):
        """An injector from a JSON rule list in the environment (None when
        unset/empty) — how subprocess workers are armed before import."""
        spec = os.environ.get(var, "").strip()
        if not spec:
            return None
        rules = json.loads(spec)
        if isinstance(rules, dict):
            rules = [rules]
        return cls(rules)

    def invocations(self, site: str) -> int:
        with self._lock:
            return self._counts.get(site, 0)

    def fired(self, site: str | None = None) -> list[tuple[str, int, str]]:
        with self._lock:
            return [e for e in self._log if site is None or e[0] == site]

    def fire(self, site: str, path: str | None = None, **info) -> None:
        with self._lock:
            n = self._counts[site] = self._counts.get(site, 0) + 1
            hits = [r for r in self.rules
                    if r.site == site and r.at <= n < r.at + r.count]
            for r in hits:
                self._log.append((site, n, r.action))
        for r in hits:      # side effects OUTSIDE the lock
            if r.action == "delay":
                # repro: allow(wallclock-traced) — the delay fault's ACTION is a wall-clock sleep; determinism lives in the rule schedule (site hit counts), not the wait itself
                time.sleep(r.delay_s)
            elif r.action == "die":
                # a real unhandled death (no atexit, no finally blocks) —
                # the same failure mode as a preempted/OOM-killed worker
                # repro: allow(host-divergence) — kills its OWN process; the pid never feeds a traced computation
                os.kill(os.getpid(), signal.SIGKILL)
            elif r.action == "truncate":
                if path is None:
                    raise ValueError(
                        f"truncate rule at site {site!r} needs the site to "
                        "pass path=")
                with open(path, "r+b") as f:
                    f.truncate(r.keep_bytes)
            else:   # "raise"
                raise InjectedFault(f"{site}[{n}]: {r.message}")


# one process-wide active injector; armed from the environment at import so
# CLI/subprocess workers need no code changes to run under faults
_active: FaultInjector | None = FaultInjector.from_env()


def active() -> FaultInjector | None:
    return _active


def fault_point(site: str, **info) -> None:
    """The hook production code calls; near-free when nothing is armed."""
    inj = _active
    if inj is not None:
        inj.fire(site, **info)


@contextlib.contextmanager
def inject(*rules):
    """Arm an injector for the duration of a with-block (in-process tests);
    yields it so the test can assert on `fired()`/`invocations()`."""
    global _active
    prev = _active
    _active = inj = FaultInjector(rules)
    try:
        yield inj
    finally:
        _active = prev


__all__ = ["FaultRule", "FaultInjector", "InjectedFault", "fault_point",
           "inject", "active"]
