"""Native AdamW (Algorithm 1's optimizer block) with decoupled weight decay,
bias correction, global-norm gradient clipping.  Optimizer moments are f32
regardless of param dtype and inherit the parameters' sharding (GSPMD shards
them like params; under the hybrid shard_map step they live on the model
axis).

`use_kernel=True` routes the elementwise update through the Pallas
`fused_adamw` TPU kernel (validated against this implementation in tests).

The `*_flat` family (DESIGN §9) is the flat-buffer path: optimizer moments
live as a few dtype-homogeneous bucketed buffers (`FlatLayout`) instead of
pytrees, and the whole clip+update tail runs as one fused launch per bucket
with the gradient's Σg² emitted as a kernel byproduct — O(buckets) ops per
step instead of O(leaves), and no redundant norm passes.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.norm_test import tree_sqnorm


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 4e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    use_kernel: bool = False


def init_adamw(params):
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(tree_sqnorm(grads))
    scale = clip_scale_from_norm(gnorm, max_norm)
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state, cfg: AdamWConfig, lr):
    """One AdamW step. lr may be a traced scalar (schedule value)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.sqrt(tree_sqnorm(grads))
    count = state["count"] + 1
    c1 = 1.0 - cfg.beta1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.beta2 ** count.astype(jnp.float32)

    if cfg.use_kernel:
        from repro.kernels.ops import fused_adamw_tree
        new_params, new_m, new_v = fused_adamw_tree(
            params, grads, state["m"], state["v"], lr=lr,
            beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, c1=c1, c2=c2)
        return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.beta1 * m + (1 - cfg.beta1) * g32
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g32)
        mhat = m / c1
        vhat = v / c2
        p32 = p.astype(jnp.float32)
        p32 = (1.0 - lr * cfg.weight_decay) * p32 - lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
        return p32.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm


# -------------------------------------------------- flat-buffer path ----

def init_adamw_flat(params, *, shard_divisor: int = 1, layout=None):
    """Moments as flat f32 buffers (tuples) matching `FlatLayout.from_tree(
    params, shard_divisor=...)` — the layout is rebuilt deterministically, so
    it is never stored in the state.  `shard_divisor` must match the step's
    layout (the data-axis worker count J when the buckets are mesh-sharded,
    DESIGN §9): bucket sizes are padded to J-divisible so each worker holds
    an exact 1/J moment shard.  Pass the step builder's shared `layout` to
    skip the rebuild (it must have been built at the same divisor)."""
    from repro.distributed.flatbuf import FlatLayout
    if layout is None:
        layout = FlatLayout.from_tree(params, shard_divisor=shard_divisor)
    return {
        "m": tuple(layout.zeros(jnp.float32)),
        "v": tuple(layout.zeros(jnp.float32)),
        "count": jnp.zeros((), jnp.int32),
    }


def flat_opt_state(params_like, state, *, shard_divisor: int = 1, layout=None):
    """Convert a tree optimizer state to the flat layout (tests/migration)."""
    from repro.distributed.flatbuf import FlatLayout
    if layout is None:
        layout = FlatLayout.from_tree(params_like, shard_divisor=shard_divisor)
    return {"m": tuple(layout.flatten(state["m"])),
            "v": tuple(layout.flatten(state["v"])),
            "count": state["count"]}


def unflat_opt_state(params_like, state, *, shard_divisor: int = 1,
                     layout=None):
    """Inverse of `flat_opt_state` (bit-exact)."""
    from repro.distributed.flatbuf import FlatLayout
    if layout is None:
        layout = FlatLayout.from_tree(params_like, shard_divisor=shard_divisor)
    return {"m": layout.unflatten(list(state["m"])),
            "v": layout.unflatten(list(state["v"])),
            "count": state["count"]}


def clip_scale_from_norm(grad_norm, grad_clip: float):
    """THE global-norm clip multiplier formula — the single definition the
    updates apply (`clip_by_global_norm`, `adamw_update_buffers`) and the
    `clip_scale` step metric reports, so the differential oracle pins the
    multiplier the update ACTUALLY used across every stats/params
    residency combination."""
    if grad_clip <= 0:
        return jnp.ones((), jnp.float32)
    return jnp.minimum(1.0, grad_clip / (grad_norm + 1e-12))


def adamw_update_buffers(pb, gb, mb, vb, cfg: AdamWConfig, lr, count, *,
                         grad_sqnorm=None):
    """The buffer-level AdamW tail: one fused launch per bucket.

    All operands are lists of flat buffers (congruent bucketing).  If the
    caller already holds Σ‖g‖² (e.g. from the fused norm-test statistics),
    pass it as `grad_sqnorm` and the clip norm costs zero extra passes;
    otherwise it comes from the update kernel's byproduct (no clipping) or
    one read-only reduction (clipping enabled).

    Returns (new_pb, new_mb, new_vb, new_count, grad_norm, grad_sqnorm).
    """
    from repro.kernels import ops

    if not len(pb) == len(gb) == len(mb) == len(vb):
        raise ValueError("flat state does not match the params layout "
                         f"({len(pb)} vs {len(mb)} buffers)")
    count = count + 1
    c1 = 1.0 - cfg.beta1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.beta2 ** count.astype(jnp.float32)

    if cfg.grad_clip > 0 and grad_sqnorm is None:
        grad_sqnorm = sum(
            (jnp.sum(jnp.square(g.astype(jnp.float32))) for g in gb),
            jnp.zeros((), jnp.float32))
    scale = (clip_scale_from_norm(jnp.sqrt(grad_sqnorm), cfg.grad_clip)
             if cfg.grad_clip > 0 else jnp.ones((), jnp.float32))

    outs = [ops.adamw_flat(p, g, m, v, lr=lr, beta1=cfg.beta1,
                           beta2=cfg.beta2, eps=cfg.eps,
                           weight_decay=cfg.weight_decay, c1=c1, c2=c2,
                           clip_scale=scale)
            for p, g, m, v in zip(pb, gb, mb, vb)]
    if grad_sqnorm is None:   # kernel byproduct: Σg² with zero extra passes
        grad_sqnorm = sum((o[3] for o in outs), jnp.zeros((), jnp.float32))
    gnorm = jnp.sqrt(grad_sqnorm)
    return ([o[0] for o in outs], [o[1] for o in outs], [o[2] for o in outs],
            count, gnorm, grad_sqnorm)


def adamw_update_flat(params, grads, state, cfg: AdamWConfig, lr, *,
                      grad_sqnorm=None, layout=None):
    """One AdamW step over flat buffers; state must come from
    `init_adamw_flat` / `flat_opt_state` (same layout/shard_divisor).

    Params arrive (and return) as the model's pytree; params/gradients are
    packed per-bucket on the way in and the updated params sliced back out
    (`adamw_update_buffers` is the pack-free core for callers that already
    hold buffers — the train steps use it directly so the mean gradient is
    packed exactly once per step).

    `layout` is the shared step-signature `FlatLayout`; omitted, it is
    rebuilt here at every trace.

    Returns (new_params, new_state, grad_norm, grad_sqnorm) — the extra
    Σ‖g‖² return (vs `adamw_update`) lets the step reuse it for the
    variance statistic and the `grad_norm` metric for free.
    """
    from repro.distributed.flatbuf import FlatLayout

    if layout is None:
        layout = FlatLayout.from_tree(params)
    pb = layout.flatten(params)
    gb = layout.flatten(grads)
    new_pb, new_mb, new_vb, count, gnorm, grad_sqnorm = adamw_update_buffers(
        pb, gb, list(state["m"]), list(state["v"]), cfg, lr, state["count"],
        grad_sqnorm=grad_sqnorm)
    new_params = layout.unflatten(new_pb)
    new_state = {"m": tuple(new_mb), "v": tuple(new_vb), "count": count}
    return new_params, new_state, gnorm, grad_sqnorm


# ------------------------------------------------------- lr schedules ----

def warmup_cosine(step, *, peak_lr: float, min_lr: float, warmup_steps: int,
                  total_steps: int):
    """Linear warmup + cosine decay (the paper's schedule, Table 5)."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = min_lr + 0.5 * (peak_lr - min_lr) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)


def constant_lr(step, *, peak_lr: float, **_):
    return jnp.asarray(peak_lr, jnp.float32)
