"""Version shims over the moving parts of the JAX API (DESIGN §0).

The repo targets the modern spelling (`jax.shard_map`, `jax.set_mesh`,
`jax.sharding.AxisType`) but must run on whatever JAX the image bakes in
(currently 0.4.37, which predates all three).  Every call site goes through
this module so the rest of the codebase never branches on versions:

* `make_mesh(shape, axes)`     — `jax.make_mesh`, passing `axis_types`
                                 (all-Auto) only when the install supports it.
* `set_mesh(mesh)`             — context manager: `jax.set_mesh` when
                                 available, else the classic `with mesh:`
                                 physical-mesh context (equivalent for our
                                 usage: bare-PartitionSpec constraint
                                 resolution + shard_map axis binding).
* `shard_map(f, mesh=..., in_specs=..., out_specs=..., axis_names=...,
             check_vma=...)`   — new-style keyword API; lowered onto
                                 `jax.experimental.shard_map.shard_map` with
                                 `auto = mesh.axis_names - axis_names` and
                                 `check_rep = check_vma` on old installs.
"""

from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType as _AxisType
except ImportError:  # 0.4.x: meshes have no axis types (everything is Auto)
    _AxisType = None

AxisType = _AxisType

# Partial-auto shard_map (manual data axes + GSPMD auto model axis) is only
# trustworthy on JAX with the native `jax.shard_map`: the 0.4.x experimental
# `auto=` path hits an XLA "Check failed: sharding.IsManualSubgroup()" crash
# whenever a model-sharded tensor flows through a while loop (layer scan,
# gradient-accumulation scan, chunked-xent scan) inside the manual region.
# Old installs therefore fall back to a FULLY-manual shard_map for the hybrid
# train steps: parameters are all-gathered at the jit boundary and replicated
# inside the step (numerically identical; memory-wasteful on model>1 meshes,
# which on 0.4.x-only hosts are CPU smoke shapes — see DESIGN §0).
PARTIAL_AUTO_OK = hasattr(jax, "shard_map")


def make_mesh(axis_shapes, axis_names, **kwargs):
    """`jax.make_mesh` with all-Auto axis types where supported."""
    if _AxisType is not None:
        kwargs.setdefault("axis_types", (_AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    """Bind `mesh` as the ambient mesh for the enclosed block."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None):
    """New-style `jax.shard_map` keyword API on any supported JAX.

    `axis_names` is the set of MANUAL axes; the rest of the mesh stays under
    GSPMD auto partitioning (old API: the complement `auto` frozenset).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma) if check_vma is not None else True,
                      auto=auto)


def process_index() -> int:
    """This host's process index in a `jax.distributed` run (0 single-host)."""
    return jax.process_index()


def process_count() -> int:
    """Number of JAX processes in the job (1 single-host)."""
    return jax.process_count()


def sync_global_devices(name: str) -> None:
    """Fleet-wide barrier over all hosts' devices.

    `multihost_utils` has lived at this path throughout 0.4.x–0.5.x, but
    every coordination call site routes through here so a future move (the
    module is experimental) touches one line, like the other shims above."""
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices(name)


__all__ = ["AxisType", "make_mesh", "set_mesh", "shard_map",
           "process_index", "process_count", "sync_global_devices"]
