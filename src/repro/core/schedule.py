"""Batch-size schedules: constant, stagewise warmup (the paper's heuristic
baseline, e.g. 2.5–2.5–95%), and the adaptive norm-test schedule (see
controller.py).  All schedules speak the same `BatchPlan` vocabulary:
global batch = workers (J) × accumulation steps (M) × per-worker microbatch.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace


class LadderShapeError(ValueError):
    """A batch's leading (accum_steps, per-step batch) dims match no ladder
    rung.  Raised by the bucketed engine BEFORE keying the compiled-step
    cache: an off-ladder shape would otherwise trace a fresh executable
    that no warmup covered and no other step will ever hit — the silent
    recompile class the ladder exists to prevent.  Callers must quantize
    through `quantize_to_ladder` + `data.pipeline.pad_to_bucket` first."""


@dataclass(frozen=True)
class BatchPlan:
    """A concrete, launchable batch configuration for one step."""
    global_batch: int
    micro_batch: int     # per-worker, per-accumulation-step sequences
    accum_steps: int     # M
    workers: int         # J

    def __post_init__(self):
        assert self.global_batch == self.workers * self.accum_steps * self.micro_batch, self


def round_plan(desired_global: int, workers: int, micro_batch: int,
               max_micro_batch: int, base_accum: int,
               max_global: int, micro_buckets: bool = True) -> BatchPlan:
    """Algorithm 1's rounding chain, adapted for shape-stable TPU steps.

    The paper fixes M and grows the microbatch (b^M = ⌈b/(JM)⌉); under XLA a
    microbatch-shape change recompiles, so we bucket the microbatch to powers
    of two in [micro_batch, max_micro_batch] and let M absorb the remainder
    (M is a host-side loop count — free to change).  The result satisfies
    b_{k+1} = J·M·b^M ≥ desired, exactly as in Algorithm 1.
    """
    desired = max(1, min(desired_global, max_global))
    # choose the microbatch bucket
    ideal_micro = max(1, math.ceil(desired / (workers * base_accum)))
    if micro_buckets:
        mb = micro_batch
        while mb * 2 <= max_micro_batch and mb * 2 <= ideal_micro:
            mb *= 2
    else:
        mb = min(max(ideal_micro, micro_batch), max_micro_batch)
    m = max(1, math.ceil(desired / (workers * mb)))
    gb = workers * m * mb
    if gb > max_global:
        m = max(1, max_global // (workers * mb))
        gb = workers * m * mb
    return BatchPlan(global_batch=gb, micro_batch=mb, accum_steps=m, workers=workers)


# ------------------------------------------------------- bucket ladder ----

def bucket_ladder(workers: int, micro_batch: int, max_micro_batch: int,
                  base_accum: int, base_global: int,
                  max_global: int) -> tuple[BatchPlan, ...]:
    """Precompute the shape-bucket ladder for the bucketed step engine
    (DESIGN §8): a geometric sequence of `BatchPlan`s whose capacities double
    from the base plan up to (and including) the `max_global` plan.

    Every rung is produced by `round_plan`, so micro-batches are the same
    powers-of-two buckets Algorithm 1's rounding uses and M absorbs the
    remainder.  Consecutive rungs share the micro-batch whenever possible, so
    growing the batch usually changes only the host-side stacked-M dimension.
    """
    rungs: list[BatchPlan] = []
    top = round_plan(max_global, workers, micro_batch, max_micro_batch,
                     base_accum, max_global)
    cap = round_plan(base_global, workers, micro_batch, max_micro_batch,
                     base_accum, max_global).global_batch
    while cap < top.global_batch:
        rungs.append(round_plan(cap, workers, micro_batch, max_micro_batch,
                                base_accum, cap))
        cap *= 2
    rungs.append(top)
    # dedupe (tiny ladders can collapse) keeping capacity order
    seen, out = set(), []
    for p in rungs:
        k = (p.micro_batch, p.accum_steps)
        if k not in seen:
            seen.add(k)
            out.append(p)
    return tuple(out)


def parse_ladder(spec: str, workers: int) -> tuple[BatchPlan, ...]:
    """Parse an explicit `--bucket-ladder` spec: 'micro:accum,micro:accum,...'
    (capacities must be strictly increasing)."""
    rungs = []
    for part in spec.split(","):
        mb, m = (int(v) for v in part.split(":"))
        rungs.append(BatchPlan(global_batch=workers * m * mb, micro_batch=mb,
                               accum_steps=m, workers=workers))
    caps = [p.global_batch for p in rungs]
    if caps != sorted(set(caps)):
        raise ValueError(f"bucket ladder capacities must increase: {caps}")
    return tuple(rungs)


def quantize_to_ladder(desired_global: int, ladder: tuple[BatchPlan, ...],
                       max_global: int | None = None) -> BatchPlan:
    """Smallest ladder rung whose capacity covers `desired_global`.

    With `max_global` set, both the request and the RESULT are capped: rungs
    above `max_global` are ineligible (an explicit --bucket-ladder may hold
    rungs beyond the controller's cap), so once the request exceeds the
    largest eligible rung, that rung is returned.  Never shrinks a request an
    eligible rung can cover.  Degenerate case — every rung above the cap —
    falls back to the smallest rung.

    The scan early-outs on the first rung above the cap, which is only
    correct on an ascending ladder — programmatically-built ladders are not
    guaranteed sorted (`parse_ladder` validates, arbitrary tuples don't), so
    capacities are sorted here before scanning rather than silently skipping
    eligible rungs."""
    desired = desired_global if max_global is None else min(desired_global,
                                                            max_global)
    ladder = tuple(sorted(ladder, key=lambda p: p.global_batch))
    best = None
    for plan in ladder:
        if max_global is not None and plan.global_batch > max_global:
            break                      # capacities ascend: rest ineligible
        best = plan
        if plan.global_batch >= desired:
            return plan
    return best if best is not None else ladder[0]


# ------------------------------------------------------------ schedules ----

class ConstantSchedule:
    """b_k = const (the paper's constant-batch baselines)."""

    def __init__(self, plan: BatchPlan):
        self.plan = plan

    def plan_for(self, samples_processed: int, total_samples: int,
                 stats=None) -> BatchPlan:
        return self.plan


class StagewiseSchedule:
    """Prespecified warmup stages, e.g. 2048–4096–8192 for 2.5–2.5–95% of
    training samples (paper §5.1 baseline mimicking Nemotron-4/GPT-3 ramps).

    Stage sizes round UP to a launchable plan: the old `round_plan(batch,
    ..., max_global=batch)` call shrank a stage whose size was not divisible
    by workers·micro_batch (the cap clamped the rounded-up plan back BELOW
    the prescribed size), and never ladder-quantized — under the bucketed
    engine such a plan's padded shape matched no rung and the run died with
    `LadderShapeError` mid-training.  Pass the engine's ladder to emit rung
    plans directly."""

    def __init__(self, stages: tuple[tuple[float, int], ...], workers: int,
                 micro_batch: int, max_micro_batch: int, base_accum: int,
                 ladder: tuple[BatchPlan, ...] | None = None):
        # stages: ((fraction_of_samples, global_batch), ...) fractions sum to 1
        assert abs(sum(f for f, _ in stages) - 1.0) < 1e-6
        self.stages = stages
        self.workers = workers
        self.micro_batch = micro_batch
        self.max_micro_batch = max_micro_batch
        self.base_accum = base_accum
        self.ladder = ladder

    def plan_for(self, samples_processed: int, total_samples: int,
                 stats=None) -> BatchPlan:
        frac = samples_processed / max(total_samples, 1)
        acc = 0.0
        batch = self.stages[-1][1]
        for f, b in self.stages:
            acc += f
            if frac < acc:
                batch = b
                break
        # no max_global cap: an indivisible stage size must round UP to the
        # covering (J·M·mb) plan, never shrink below the prescribed stage
        plan = round_plan(batch, self.workers, self.micro_batch,
                          self.max_micro_batch, self.base_accum,
                          max_global=_UNCAPPED, micro_buckets=True)
        if self.ladder:
            # quantize onto a rung only AT or ABOVE the ladder floor: a stage
            # below the smallest rung runs padded into the floor bucket (the
            # engine's standard sub-rung path) — inflating it to the floor
            # would consume more samples than the stage prescribes
            floor = min(p.global_batch for p in self.ladder)
            if plan.global_batch >= floor:
                plan = quantize_to_ladder(plan.global_batch, self.ladder)
        return plan


# large enough that round_plan's max_global clamp never engages (stagewise
# rounding must only ever round UP); not sys.maxsize so the math stays exact
_UNCAPPED = 1 << 40


# ------------------------------------------------- accumulation-free ----

def accum_free_plan(plan: BatchPlan) -> tuple[BatchPlan, int]:
    """Re-plan an accumulated step as `accum_steps` optimizer steps of the
    same microbatch with M=1 (Marek et al., "Gradient Accumulation Is
    Wasteful"): on rungs where the whole per-step batch fits per device,
    accumulation buys nothing — trade it for proportionally more optimizer
    steps.  Returns (sub_plan, repeats) with sub_plan.global_batch ·
    repeats == plan.global_batch, so the schedule consumes exactly the same
    samples (DESIGN §14 equivalence claim)."""
    sub = BatchPlan(global_batch=plan.workers * plan.micro_batch,
                    micro_batch=plan.micro_batch, accum_steps=1,
                    workers=plan.workers)
    return sub, plan.accum_steps
