"""Gradient noise scale (McCandlish et al. 2018) from norm-test statistics.

The paper's §5.4 conjectures a relation between the norm-test threshold η and
the *critical batch size*.  The GNS B_simple = tr(Σ)/‖∇L‖² is exactly
computable from the two scalars the norm test already produces:

    ‖Var̂‖₁ = (1/J)Σ_j ‖g_j − g‖²  estimates  tr(Σ)/b_worker = tr(Σ)·J/b
    ⇒  tr(Σ) ≈ var_l1 · b / J      and  B_simple = tr(Σ)/‖g‖².

Algorithm 1's growth target is b_{k+1} = ‖Var̂‖₁/(η²‖g‖²) = B_simple/(η²·J/b)…
collapsing the algebra:   b_{k+1} = B_simple / (η² · J) · (J/b) · b … i.e.

    b_{k+1} · η² = B_simple · (J / b_k)        (per-worker form)

so for J = b (per-sample workers) the norm test with threshold η grows the
batch to exactly B_simple/η² — the norm test IS a thresholded
gradient-noise-scale controller.  `examples/gns_tracking.py` demonstrates the
relation empirically; the unbiased running estimator below matches
McCandlish's two-scale trick using (b_small, b_big) = (b/J, b).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp


def gns_from_norm_test(var_l1: float, grad_sqnorm: float, global_batch: int,
                       workers: int) -> dict:
    """Point estimates of tr(Σ) and B_simple from one step's statistics."""
    tr_sigma = float(var_l1) * global_batch / max(workers, 1)
    b_simple = tr_sigma / max(float(grad_sqnorm), 1e-30)
    return {"tr_sigma": tr_sigma, "b_simple": b_simple}


def unbiased_gns_pair(var_l1: float, grad_sqnorm: float, global_batch: int,
                      workers: int) -> dict:
    """McCandlish's unbiased two-batch-size estimator using the worker
    minibatch (b_small = b/J, its mean-square-norm = ‖g‖² + var_l1) and the
    global batch (b_big = b):

        |G|² := (b_big·‖G_big‖² − b_small·‖G_small‖²)/(b_big − b_small)
        S    := (‖G_small‖² − ‖G_big‖²)/(1/b_small − 1/b_big)
        B_simple = S / |G|²
    """
    b_big = float(global_batch)
    b_small = b_big / max(workers, 1)
    if workers <= 1:
        return {"g2": float(grad_sqnorm), "s": 0.0, "b_simple": 0.0}
    gsmall_sq = float(grad_sqnorm) + float(var_l1)   # E‖g_j‖² = ‖g‖² + E‖g_j−g‖²
    gbig_sq = float(grad_sqnorm)
    g2 = (b_big * gbig_sq - b_small * gsmall_sq) / (b_big - b_small)
    s = (gsmall_sq - gbig_sq) / (1.0 / b_small - 1.0 / b_big)
    return {"g2": g2, "s": s, "b_simple": s / g2 if g2 > 0 else float("inf")}


@dataclass(frozen=True)
class GNSTracker:
    """EMA-smoothed running GNS (McCandlish appendix A.1 recommends separate
    EMAs of S and |G|² rather than of their ratio)."""
    alpha: float = 0.9
    s_ema: float = 0.0
    g2_ema: float = 0.0
    initialized: bool = False

    def update(self, var_l1: float, grad_sqnorm: float, global_batch: int,
               workers: int) -> "GNSTracker":
        est = unbiased_gns_pair(var_l1, grad_sqnorm, global_batch, workers)
        if not self.initialized:
            return GNSTracker(self.alpha, est["s"], est["g2"], True)
        a = self.alpha
        return GNSTracker(self.alpha, a * self.s_ema + (1 - a) * est["s"],
                          a * self.g2_ema + (1 - a) * est["g2"], True)

    @property
    def b_simple(self) -> float:
        if not self.initialized or self.g2_ema <= 0:
            return 0.0
        return self.s_ema / self.g2_ema
