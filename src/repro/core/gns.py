"""Gradient noise scale (McCandlish et al. 2018) from norm-test statistics.

The paper's §5.4 conjectures a relation between the norm-test threshold η and
the *critical batch size*.  The GNS B_simple = tr(Σ)/‖∇L‖² is exactly
computable from the two scalars the norm test already produces:

    ‖Var̂‖₁ = (1/J)Σ_j ‖g_j − g‖²  estimates  tr(Σ)/b_worker = tr(Σ)·J/b
    ⇒  tr(Σ) ≈ var_l1 · b / J      and  B_simple = tr(Σ)/‖g‖².

Algorithm 1's growth target is b_{k+1} = ‖Var̂‖₁/(η²‖g‖²) = B_simple/(η²·J/b)…
collapsing the algebra:   b_{k+1} = B_simple / (η² · J) · (J/b) · b … i.e.

    b_{k+1} · η² = B_simple · (J / b_k)        (per-worker form)

so for J = b (per-sample workers) the norm test with threshold η grows the
batch to exactly B_simple/η² — the norm test IS a thresholded
gradient-noise-scale controller.  `examples/gns_tracking.py` demonstrates the
relation empirically; the unbiased running estimator below matches
McCandlish's two-scale trick using (b_small, b_big) = (b/G, b).

Variance groups
---------------
Both step implementations report `var_l1` on the *per-worker* (J) scale, but
the number of independent variance groups the statistic actually averages
over differs: FSDP-Norm compares J worker gradients (G = J), ACCUM-NORM
compares the M accumulation microbatch gradients on each of J workers
(G = M·J).  The two-scale estimator needs the GROUP count — with the old
hardwired `workers` an ACCUM-NORM J=1 run degenerated to b_small == b_big
and silently returned b_simple = 0 (a dead GNS signal).  `variance_groups`
defines the count once; the estimators convert var_l1 from the J scale to
the group scale internally (var_G = var_l1 · G / J).

Prediction
----------
The controller in `core/controller.py` carries a `GNSTracker` to turn the
smoothed B_simple trajectory into (a) an ETA until the norm test next fires
and (b) the ladder rung it will land on — used to AOT-warm the *predicted*
rung instead of blindly the next one (DESIGN §14).  The crossing level
accounts for the noise inflation of the measured ‖G_b‖²:

    T(b) = var_l1/(η²·‖G_b‖²),  var_l1 = tr(Σ)·J/b,  ‖G_b‖² ≈ |G|²(1 + B/b)
    T > b  ⟺  B·(J/b − η²) > η²·b  ⟺  B > η²·b²/(J − η²·b)   when J > η²·b

(and the test can never fire at b when J ≤ η²·b: the measured gradient norm
grows with the noise as fast as the variance does).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


def variance_groups(step_impl: str, workers: int, accum_steps: int = 1) -> int:
    """The number of independent variance groups `var_l1` averages over:
    J for FSDP-Norm (worker gradients), M·J for ACCUM-NORM (accumulation
    microbatch gradients on every worker)."""
    j = max(int(workers), 1)
    if step_impl == "accum_norm":
        return j * max(int(accum_steps), 1)
    return j


def gns_from_norm_test(var_l1: float, grad_sqnorm: float, global_batch: int,
                       workers: int) -> dict:
    """Point estimates of tr(Σ) and B_simple from one step's statistics.
    `workers` is the J scale var_l1 arrives on (NOT the group count — both
    step impls emit var_l1 ≈ tr(Σ)·J/b regardless of grouping)."""
    tr_sigma = float(var_l1) * global_batch / max(workers, 1)
    b_simple = tr_sigma / max(float(grad_sqnorm), 1e-30)
    return {"tr_sigma": tr_sigma, "b_simple": b_simple}


def unbiased_gns_pair(var_l1: float, grad_sqnorm: float, global_batch: int,
                      workers: int, groups: int | None = None) -> dict:
    """McCandlish's unbiased two-batch-size estimator using the variance
    group minibatch (b_small = b/G, its mean-square-norm = ‖g‖² + var_G) and
    the global batch (b_big = b):

        |G|² := (b_big·‖G_big‖² − b_small·‖G_small‖²)/(b_big − b_small)
        S    := (‖G_small‖² − ‖G_big‖²)/(1/b_small − 1/b_big)
        B_simple = S / |G|²

    `groups` is the variance-group count G (`variance_groups`); it defaults
    to `workers` (the FSDP-Norm case, preserving the original signature).
    var_l1 always arrives on the J scale and is converted to the group
    scale internally.  Degenerate inputs — one group (no two-scale signal)
    or a non-positive/non-finite |G|² estimate (noise swamping the mean
    gradient) — return a CLAMPED b_simple of 0.0 with valid=False instead
    of the old silent 0.0 / inf, so downstream smoothing can skip them."""
    g = max(int(groups if groups is not None else workers), 1)
    b_big = float(global_batch)
    b_small = b_big / g
    if g <= 1:
        return {"g2": float(grad_sqnorm), "s": 0.0, "b_simple": 0.0,
                "valid": False}
    var_g = float(var_l1) * g / max(workers, 1)   # J scale -> group scale
    gsmall_sq = float(grad_sqnorm) + var_g   # E‖g_i‖² = ‖g‖² + E‖g_i−g‖²
    gbig_sq = float(grad_sqnorm)
    g2 = (b_big * gbig_sq - b_small * gsmall_sq) / (b_big - b_small)
    s = (gsmall_sq - gbig_sq) / (1.0 / b_small - 1.0 / b_big)
    valid = math.isfinite(g2) and math.isfinite(s) and g2 > 0.0
    return {"g2": g2, "s": s, "b_simple": s / g2 if valid else 0.0,
            "valid": valid}


@dataclass(frozen=True)
class GNSTracker:
    """EMA-smoothed running GNS (McCandlish appendix A.1 recommends separate
    EMAs of S and |G|² rather than of their ratio).  The first VALID
    observation seeds both EMAs (no blend against the 0.0 placeholders);
    degenerate or non-finite estimates are skipped — they never reach the
    smoothed trajectory the predictor fits."""
    alpha: float = 0.9
    s_ema: float = 0.0
    g2_ema: float = 0.0
    initialized: bool = False

    def update(self, var_l1: float, grad_sqnorm: float, global_batch: int,
               workers: int, groups: int | None = None) -> "GNSTracker":
        est = unbiased_gns_pair(var_l1, grad_sqnorm, global_batch, workers,
                                groups=groups)
        if not est["valid"]:
            return self
        if not self.initialized:
            return GNSTracker(self.alpha, est["s"], est["g2"], True)
        a = self.alpha
        return GNSTracker(self.alpha, a * self.s_ema + (1 - a) * est["s"],
                          a * self.g2_ema + (1 - a) * est["g2"], True)

    @property
    def b_simple(self) -> float:
        if not self.initialized or self.g2_ema <= 0:
            return 0.0
        return self.s_ema / self.g2_ema


# ------------------------------------------------------------ prediction ----

def critical_gns_at(batch: int, eta: float, workers: int) -> float:
    """B_cross(b): the smoothed-GNS level above which the norm test fires at
    global batch `b` (module docstring derivation).  inf when J ≤ η²·b —
    the measured gradient norm inflates with the noise, so no noise level
    can fire the test at that rung."""
    denom = float(workers) - eta * eta * float(batch)
    if denom <= 0.0:
        return float("inf")
    return eta * eta * float(batch) ** 2 / denom


def rung_crossing_eta(b_simple: float, slope: float, batch: int, eta: float,
                      workers: int) -> float:
    """Tested-steps until the norm test fires at the current batch: 0.0 when
    the smoothed GNS already exceeds the crossing level, -1.0 when
    unknowable (non-growing GNS, or an uncrossable rung).  The -1.0
    sentinel (not inf/nan) keeps the value exactly JSON-round-trippable
    inside checkpointed controller state."""
    cross = critical_gns_at(batch, eta, workers)
    if b_simple >= cross:
        return 0.0
    if slope <= 0.0 or not math.isfinite(cross):
        return -1.0
    return (cross - b_simple) / slope


def predict_target_batch(b_simple: float, slope: float, horizon: float,
                         batch: int, eta: float, workers: int,
                         rungs) -> int:
    """The ladder rung the controller is headed for: the smallest rung ≥ the
    current batch at which the horizon-projected GNS no longer fires the
    test (B_proj ≤ B_cross), i.e. where the controller would be stable.
    Projection runs the slope forward `horizon` tested steps; a projection
    above every rung's crossing level lands on the top rung.  Returns the
    rung's global batch, or 0 when there is no ladder to predict onto."""
    rungs = sorted(int(r) for r in (rungs or ()))
    if not rungs:
        return 0
    b_proj = b_simple + max(slope, 0.0) * float(horizon)
    for r in rungs:
        if r < batch:
            continue
        if b_proj <= critical_gns_at(r, eta, workers):
            return r
    return rungs[-1]
