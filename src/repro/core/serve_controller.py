"""Admission/batching controller for the serving tier (DESIGN §11).

The serving analog of Algorithm 1 (`core.controller`): where the training
controller adapts the batch size to the measured gradient noise
(T_k vs b_k), this one adapts the active request-batch RUNG to the measured
load.  Same vocabulary, same shape discipline: decisions land on a
powers-of-two ladder so every rung change is a precompiled-step lookup in
the serve engine, never a recompile.

Inputs per decision (one decision per engine step):
  * demand  = in-flight + queued requests — the serving counterpart of the
    norm-test statistic: it says how big the batch WANTS to be;
  * a per-rung step-latency EMA — measured, not modeled, mirroring how the
    training side trusts measured dynamics over static schedules.  Growth
    into a rung whose measured step time already exceeds the latency budget
    is vetoed (bigger batches raise throughput but stretch every in-flight
    token's step clock).

Hysteresis: growth is eager (patience 1 by default — queued requests are
waiting), shrink requires `shrink_patience` consecutive slack decisions so a
burst trough doesn't thrash the rung.  Both mirrors of the training
controller's monotone-growth bias, adapted to a workload that does drain.

The per-rung latency EMA carries an explicit initialized flag per rung —
the training controller's cold-start lesson (its `state.step > 0` proxy
blended the first real observation against a 0.0 placeholder and delayed
the first increase; see ControllerState.ema_init).
"""

from __future__ import annotations

from dataclasses import dataclass, replace


def serve_ladder(max_batch: int) -> tuple[int, ...]:
    """Powers-of-two request-batch rungs 1, 2, 4, ... up to (and including)
    `max_batch`; a non-power-of-two cap becomes the explicit top rung."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    rungs = []
    b = 1
    while b < max_batch:
        rungs.append(b)
        b *= 2
    rungs.append(max_batch)
    return tuple(rungs)


def quantize_batch(desired: int, ladder: tuple[int, ...]) -> int:
    """Smallest rung covering `desired` (the top rung when nothing does)."""
    for b in ladder:
        if b >= desired:
            return b
    return ladder[-1]


@dataclass(frozen=True)
class ServeControllerConfig:
    ladder: tuple[int, ...]          # ascending request-batch rungs
    grow_patience: int = 1           # consecutive over-demand decisions
    shrink_patience: int = 4         # consecutive slack decisions
    latency_slo_s: float = 0.0       # per-step budget; 0 disables the guard
    ema: float = 0.5                 # per-rung step-latency EMA weight

    def __post_init__(self):
        caps = list(self.ladder)
        if not caps or caps != sorted(set(caps)) or caps[0] < 1:
            raise ValueError(
                f"serve ladder must be ascending positive rungs: {caps}")
        if self.grow_patience < 1 or self.shrink_patience < 1:
            raise ValueError("patience values must be >= 1")


@dataclass(frozen=True)
class ServeControllerState:
    rung: int = 0                    # index into cfg.ladder
    decisions: int = 0
    grow_streak: int = 0
    shrink_streak: int = 0
    rung_changes: int = 0
    latency_vetoes: int = 0          # growths blocked by the latency guard
    # per-rung measured step latency: EMA value + explicit initialized flag
    lat_ema: tuple[float, ...] = ()
    lat_init: tuple[bool, ...] = ()


def init_serve_controller(cfg: ServeControllerConfig) -> ServeControllerState:
    n = len(cfg.ladder)
    return ServeControllerState(lat_ema=(0.0,) * n, lat_init=(False,) * n)


def observe_step_latency(cfg: ServeControllerConfig,
                         state: ServeControllerState,
                         rung: int, step_time_s: float) -> ServeControllerState:
    """Fold one measured engine-step wall time into that rung's EMA.  The
    first observation SEEDS the EMA (explicit init flag — never blended
    against the 0.0 placeholder)."""
    ema = list(state.lat_ema)
    init = list(state.lat_init)
    ema[rung] = (cfg.ema * ema[rung] + (1 - cfg.ema) * step_time_s
                 if init[rung] else step_time_s)
    init[rung] = True
    return replace(state, lat_ema=tuple(ema), lat_init=tuple(init))


def serve_controller_update(cfg: ServeControllerConfig,
                            state: ServeControllerState,
                            *, queued: int, active: int) -> ServeControllerState:
    """One admission decision: pick the rung the NEXT engine step runs at.

    Grow when demand exceeds the current rung's capacity for
    `grow_patience` consecutive decisions and the target rung's measured
    latency (when known) fits the SLO; shrink when demand fits entirely in
    the next-lower rung for `shrink_patience` consecutive decisions.
    Demand includes the in-flight requests, so a shrink never cuts below
    the active batch."""
    demand = queued + active
    rung = state.rung
    cap = cfg.ladder[rung]
    decisions = state.decisions + 1

    if demand > cap and rung + 1 < len(cfg.ladder):
        grow_streak = state.grow_streak + 1
        if grow_streak >= cfg.grow_patience:
            target = rung + 1
            if (cfg.latency_slo_s > 0 and state.lat_init[target]
                    and state.lat_ema[target] > cfg.latency_slo_s):
                return replace(state, decisions=decisions,
                               grow_streak=grow_streak, shrink_streak=0,
                               latency_vetoes=state.latency_vetoes + 1)
            return replace(state, rung=target, decisions=decisions,
                           grow_streak=0, shrink_streak=0,
                           rung_changes=state.rung_changes + 1)
        return replace(state, decisions=decisions, grow_streak=grow_streak,
                       shrink_streak=0)

    if rung > 0 and demand <= cfg.ladder[rung - 1]:
        shrink_streak = state.shrink_streak + 1
        if shrink_streak >= cfg.shrink_patience:
            return replace(state, rung=rung - 1, decisions=decisions,
                           grow_streak=0, shrink_streak=0,
                           rung_changes=state.rung_changes + 1)
        return replace(state, decisions=decisions, grow_streak=0,
                       shrink_streak=shrink_streak)

    return replace(state, decisions=decisions, grow_streak=0, shrink_streak=0)


__all__ = [
    "ServeControllerConfig", "ServeControllerState", "init_serve_controller",
    "observe_step_latency", "serve_controller_update", "serve_ladder",
    "quantize_batch",
]
