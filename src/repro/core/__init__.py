from repro.core.norm_test import (
    per_sample_norm_test, worker_variance_stats,
    paper_faithful_worker_variance, accum_variance_stats,
    tree_sqnorm, tree_sqdiff,
)
from repro.core.schedule import BatchPlan, round_plan, ConstantSchedule, StagewiseSchedule
from repro.core.controller import (
    ControllerConfig, ControllerState, init_controller, controller_update,
    norm_test_statistic,
)
