"""Testable consequences of the paper's convergence theory (§4, Appendix B).

The paper proves convergence of Adam under the *coordinate-wise exact
variance norm test* (Proposition 1 ⇒ coordinate-wise E-SG), with a
feasibility condition on (β₁, β₂).  We do not re-prove; we implement the
checkable pieces:

* `coordinate_norm_test_holds` — the coordinate-wise exact-variance test on
  materialized per-sample gradients (eq. in Prop. 1's premise);
* `esg_constant` — the empirical coordinate-wise E-SG constant
  max_i E[(∂_i L_B)²] / (∂_i L)², which Prop. 1 bounds by 1+η²;
* `adam_beta_condition` — Theorem 1's sufficient condition
  0 < β₁ ≤ √β₂ − 8(1+η²)(1−β₂)/β₂².  NOTE (recorded in DESIGN): with the
  paper's own training hyperparameters (β₁, β₂) = (0.9, 0.95) and any η,
  the sufficient condition is violated (√0.95 − 8(1+η²)·0.05/0.9025 ≈
  0.53 − 0.44η² < 0.9) — the theorem's constants are conservative relative
  to practice, as is typical for Adam analyses; training remains stable.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def per_coordinate_stats(per_sample_grads):
    """per_sample_grads: pytree with leading sample axis.
    Returns (mean_grad, second_moment_of_batchmean_estimate) flattened."""
    flat = jnp.concatenate([
        g.reshape(g.shape[0], -1).astype(jnp.float32)
        for g in jax.tree.leaves(per_sample_grads)], axis=1)       # (n, d)
    mean = jnp.mean(flat, axis=0)
    var = jnp.var(flat, axis=0, ddof=1)
    return mean, var


def coordinate_norm_test_holds(per_sample_grads, eta: float, batch_size: int):
    """Coordinate-wise exact-variance norm test: for every coordinate i,
    E[(∂_i L_B − ∂_i L)²] = Var_i / b ≤ η² (∂_i L)²."""
    mean, var = per_coordinate_stats(per_sample_grads)
    lhs = var / batch_size
    rhs = eta**2 * jnp.square(mean)
    return jnp.all(lhs <= rhs + 1e-12)


def esg_constant(per_sample_grads, batch_size: int):
    """Empirical coordinate-wise E-SG constant:
    max_i E[(∂_i L_B)²] / (∂_i L)²  (Prop. 1: ≤ 1+η² under the test)."""
    mean, var = per_coordinate_stats(per_sample_grads)
    second = jnp.square(mean) + var / batch_size
    denom = jnp.square(mean)
    ratio = jnp.where(denom > 1e-20, second / jnp.maximum(denom, 1e-20), 1.0)
    return jnp.max(ratio)


def adam_beta_condition(beta1: float, beta2: float, eta: float) -> dict:
    """Theorem 1's sufficient condition on (β₁, β₂): returns the bound and
    whether it holds."""
    bound = math.sqrt(beta2) - 8.0 * (1.0 + eta**2) * (1.0 - beta2) / beta2**2
    return {"beta1_bound": bound, "holds": 0.0 < beta1 <= bound}


def minimal_batch_for_coordinate_test(per_sample_grads, eta: float) -> jax.Array:
    """Smallest b such that the coordinate-wise exact-variance test holds
    (the quantity Algorithm 1 implicitly targets): b* = max_i Var_i/(η²·g_i²)."""
    mean, var = per_coordinate_stats(per_sample_grads)
    denom = eta**2 * jnp.square(mean)
    b = jnp.where(denom > 1e-20, var / jnp.maximum(denom, 1e-20), 0.0)
    return jnp.ceil(jnp.max(b))
