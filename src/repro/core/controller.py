"""Algorithm 1: the adaptive batch-size controller (host-side state machine).

Consumes the (var_l1, grad_sqnorm) statistics produced on-device by
`core.norm_test` and decides the next step's `BatchPlan`:

    T_k = ‖Var̂‖₁ / (η² ‖g‖²)
    if T_k > b_k:  b_{k+1} = ⌈T_k⌉  (rounded via `round_plan`, clamped)
    else:          b_{k+1} = b_k

Extras beyond Algorithm 1 (all off by default, recorded in DESIGN §7):
  * test_interval > 1 — run the test every N steps (the paper mentions this
    as the overhead-reduction knob; interval 1 is the paper's setting);
  * EMA smoothing of T_k to de-noise single-step spikes;
  * `monotonic` — never shrink the batch (the paper's test only grows; we
    keep the flag explicit so ablations can allow shrinking).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace

from repro.core.schedule import BatchPlan, quantize_to_ladder, round_plan


@dataclass(frozen=True)
class ControllerConfig:
    eta: float = 0.15
    workers: int = 1
    base_micro_batch: int = 4
    max_micro_batch: int = 8
    base_accum: int = 16
    base_global_batch: int = 256
    max_global_batch: int = 8192
    test_interval: int = 1
    ema: float = 0.0              # 0 = off (paper-faithful)
    monotonic: bool = True
    # optional shape-bucket ladder (DESIGN §8): when set, every emitted plan
    # is quantized UP onto a ladder rung, so a batch increase reuses a
    # precompiled step instead of recompiling; None = paper-exact rounding
    ladder: tuple[BatchPlan, ...] | None = None


def _resolve_plan(cfg: ControllerConfig, desired: int) -> BatchPlan:
    plan = round_plan(desired, cfg.workers, cfg.base_micro_batch,
                      cfg.max_micro_batch, cfg.base_accum,
                      cfg.max_global_batch)
    if cfg.ladder:
        plan = quantize_to_ladder(plan.global_batch, cfg.ladder,
                                  cfg.max_global_batch)
    return plan


@dataclass(frozen=True)
class ControllerState:
    plan: BatchPlan
    step: int = 0
    samples: int = 0
    ema_stat: float = 0.0
    # whether ema_stat holds a real observation yet.  `state.step > 0` is NOT
    # a valid proxy: with test_interval > 1 the first tested step arrives at
    # step >= 1 with ema_stat still at its 0.0 placeholder, and blending
    # against it biased T toward 0, delaying the first batch increase.
    ema_init: bool = False
    last_T: float = 0.0
    num_increases: int = 0
    at_max: bool = False


def init_controller(cfg: ControllerConfig) -> ControllerState:
    return ControllerState(plan=_resolve_plan(cfg, cfg.base_global_batch))


# ------------------------------------------- state (de)serialization ----
#
# The controller is half the training loop's host-side state (the other
# half — params/opt — lives on device): crash-safe checkpointing must
# capture it EXACTLY or a resumed run re-derives a different batch
# trajectory and bit-identity with the uninterrupted run is lost.  JSON
# round-trips Python floats exactly (repr-based shortest form), so
# ema_stat/last_T survive the hop bit-for-bit.

def controller_state_as_dict(state: ControllerState) -> dict:
    """JSON-safe snapshot of the full controller state (checkpoint
    metadata); `controller_state_from_dict` is the exact inverse."""
    return dataclasses.asdict(state)


def controller_state_from_dict(d: dict) -> ControllerState:
    """Rebuild a `ControllerState` saved by `controller_state_as_dict`."""
    plan = BatchPlan(**{k: int(v) for k, v in d["plan"].items()})
    return ControllerState(
        plan=plan, step=int(d["step"]), samples=int(d["samples"]),
        ema_stat=float(d["ema_stat"]), ema_init=bool(d["ema_init"]),
        last_T=float(d["last_T"]), num_increases=int(d["num_increases"]),
        at_max=bool(d["at_max"]))


def norm_test_statistic(var_l1: float, grad_sqnorm: float, eta: float) -> float:
    return float(var_l1) / (eta**2 * float(grad_sqnorm) + 1e-30)


def controller_update(cfg: ControllerConfig, state: ControllerState,
                      var_l1: float, grad_sqnorm: float) -> ControllerState:
    """One Algorithm-1 update after an optimizer step."""
    new_samples = state.samples + state.plan.global_batch
    step = state.step + 1

    # max-batch shortcut: the paper stops testing once b_k == max
    if state.at_max or (cfg.test_interval > 1 and step % cfg.test_interval != 0):
        return replace(state, step=step, samples=new_samples)

    t_raw = norm_test_statistic(var_l1, grad_sqnorm, cfg.eta)
    if cfg.ema > 0:
        ema = cfg.ema * state.ema_stat + (1 - cfg.ema) * t_raw \
            if state.ema_init else t_raw
        t_eff = ema
    else:
        ema = t_raw
        t_eff = t_raw

    b_k = state.plan.global_batch
    if t_eff > b_k:
        desired = math.ceil(t_eff)
        if cfg.monotonic:
            desired = max(desired, b_k)
        plan = _resolve_plan(cfg, desired)
        if cfg.monotonic and plan.global_batch < b_k:
            plan = state.plan
        increased = plan.global_batch > b_k
        # the reachable ceiling: the largest ladder rung the cap permits —
        # a ladder whose top rung rounds below max_global_batch still
        # latches there (nothing larger is eligible)
        cap = cfg.max_global_batch
        if cfg.ladder:
            cap = max((p.global_batch for p in cfg.ladder
                       if p.global_batch <= cfg.max_global_batch),
                      default=cfg.ladder[0].global_batch)
        return ControllerState(
            plan=plan, step=step, samples=new_samples, ema_stat=ema,
            ema_init=True, last_T=t_raw,
            num_increases=state.num_increases + int(increased),
            at_max=plan.global_batch >= min(cfg.max_global_batch, cap))
    return replace(state, step=step, samples=new_samples, ema_stat=ema,
                   ema_init=True, last_T=t_raw)
