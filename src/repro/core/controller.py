"""Algorithm 1: the adaptive batch-size controller (host-side state machine).

Consumes the (var_l1, grad_sqnorm) statistics produced on-device by
`core.norm_test` and decides the next step's `BatchPlan`:

    T_k = ‖Var̂‖₁ / (η² ‖g‖²)
    if T_k > b_k:  b_{k+1} = ⌈T_k⌉  (rounded via `round_plan`, clamped)
    else:          b_{k+1} = b_k

Extras beyond Algorithm 1 (all off by default, recorded in DESIGN §7):
  * test_interval > 1 — run the test every N steps (the paper mentions this
    as the overhead-reduction knob; interval 1 is the paper's setting);
  * EMA smoothing of T_k to de-noise single-step spikes;
  * `monotonic` — never shrink the batch (the paper's test only grows; we
    keep the flag explicit so ablations can allow shrinking).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace

from repro.core.gns import (
    GNSTracker, predict_target_batch, rung_crossing_eta, variance_groups)
from repro.core.schedule import BatchPlan, quantize_to_ladder, round_plan


@dataclass(frozen=True)
class ControllerConfig:
    eta: float = 0.15
    workers: int = 1
    base_micro_batch: int = 4
    max_micro_batch: int = 8
    base_accum: int = 16
    base_global_batch: int = 256
    max_global_batch: int = 8192
    test_interval: int = 1
    ema: float = 0.0              # 0 = off (paper-faithful)
    monotonic: bool = True
    # optional shape-bucket ladder (DESIGN §8): when set, every emitted plan
    # is quantized UP onto a ladder rung, so a batch increase reuses a
    # precompiled step instead of recompiling; None = paper-exact rounding
    ladder: tuple[BatchPlan, ...] | None = None
    # predictive GNS companion (DESIGN §14): when on, every tested step also
    # feeds the (var_l1, grad_sqnorm) pair into an EMA-smoothed unbiased GNS
    # estimate whose trajectory predicts WHICH rung the controller will jump
    # to and WHEN — carried in ControllerState for the engine's AOT-warmup
    # targeting.  Prediction NEVER alters the batch trajectory: with
    # predict=True and predict=False the emitted plans are identical, which
    # is what lets pre-predictor checkpoints resume bit-identically with a
    # zeroed predictor.
    predict: bool = False
    gns_alpha: float = 0.9        # EMA over the S and |G|² estimates
    # variance-group source for the two-scale estimator: 'workers' = J
    # groups (FSDP-Norm), 'accum' = M·J groups (ACCUM-NORM) — see
    # core.gns.variance_groups
    gns_groups: str = "workers"
    slope_alpha: float = 0.5      # EMA over the per-tested-step ΔB_simple
    predict_horizon: int = 5      # tested-steps lookahead for the target rung


def _resolve_plan(cfg: ControllerConfig, desired: int) -> BatchPlan:
    plan = round_plan(desired, cfg.workers, cfg.base_micro_batch,
                      cfg.max_micro_batch, cfg.base_accum,
                      cfg.max_global_batch)
    if cfg.ladder:
        plan = quantize_to_ladder(plan.global_batch, cfg.ladder,
                                  cfg.max_global_batch)
    return plan


@dataclass(frozen=True)
class ControllerState:
    plan: BatchPlan
    step: int = 0
    samples: int = 0
    ema_stat: float = 0.0
    # whether ema_stat holds a real observation yet.  `state.step > 0` is NOT
    # a valid proxy: with test_interval > 1 the first tested step arrives at
    # step >= 1 with ema_stat still at its 0.0 placeholder, and blending
    # against it biased T toward 0, delaying the first batch increase.
    ema_init: bool = False
    last_T: float = 0.0
    num_increases: int = 0
    at_max: bool = False
    # predictive-GNS companion state (DESIGN §14; all inert defaults unless
    # cfg.predict).  Flat scalars, not a nested GNSTracker, so the JSON
    # checkpoint round-trip stays a plain dict of primitives.
    gns_s: float = 0.0            # EMA of the S (tr Σ) estimate
    gns_g2: float = 0.0           # EMA of the |G|² estimate
    gns_init: bool = False        # EMAs hold a real (valid) observation
    gns_b_prev: float = 0.0       # previous smoothed B_simple (slope input)
    gns_slope: float = 0.0        # EMA of per-tested-step ΔB_simple
    gns_slope_init: bool = False
    pred_rung: int = 0            # predicted target rung (global batch); 0 = none
    pred_eta_steps: float = -1.0  # tested-steps to crossing; -1 = unknown


def init_controller(cfg: ControllerConfig) -> ControllerState:
    return ControllerState(plan=_resolve_plan(cfg, cfg.base_global_batch))


# ------------------------------------------- state (de)serialization ----
#
# The controller is half the training loop's host-side state (the other
# half — params/opt — lives on device): crash-safe checkpointing must
# capture it EXACTLY or a resumed run re-derives a different batch
# trajectory and bit-identity with the uninterrupted run is lost.  JSON
# round-trips Python floats exactly (repr-based shortest form), so
# ema_stat/last_T survive the hop bit-for-bit.

def controller_state_as_dict(state: ControllerState) -> dict:
    """JSON-safe snapshot of the full controller state (checkpoint
    metadata); `controller_state_from_dict` is the exact inverse."""
    return dataclasses.asdict(state)


def controller_state_from_dict(d: dict) -> ControllerState:
    """Rebuild a `ControllerState` saved by `controller_state_as_dict`.

    The predictor fields load with SAFE DEFAULTS when absent (a checkpoint
    written before the predictor existed): prediction only steers AOT-warmup
    targeting, never the batch trajectory, so a zeroed predictor re-seeds
    itself on the next tested step and the resumed run's losses/batches stay
    bit-identical to the uninterrupted one — a loud error would make old
    checkpoints unloadable for zero correctness gain."""
    plan = BatchPlan(**{k: int(v) for k, v in d["plan"].items()})
    return ControllerState(
        plan=plan, step=int(d["step"]), samples=int(d["samples"]),
        ema_stat=float(d["ema_stat"]), ema_init=bool(d["ema_init"]),
        last_T=float(d["last_T"]), num_increases=int(d["num_increases"]),
        at_max=bool(d["at_max"]),
        gns_s=float(d.get("gns_s", 0.0)), gns_g2=float(d.get("gns_g2", 0.0)),
        gns_init=bool(d.get("gns_init", False)),
        gns_b_prev=float(d.get("gns_b_prev", 0.0)),
        gns_slope=float(d.get("gns_slope", 0.0)),
        gns_slope_init=bool(d.get("gns_slope_init", False)),
        pred_rung=int(d.get("pred_rung", 0)),
        pred_eta_steps=float(d.get("pred_eta_steps", -1.0)))


def norm_test_statistic(var_l1: float, grad_sqnorm: float, eta: float) -> float:
    return float(var_l1) / (eta**2 * float(grad_sqnorm) + 1e-30)


def _predictor_fields(cfg: ControllerConfig, state: ControllerState,
                      var_l1: float, grad_sqnorm: float) -> dict:
    """One predictive-GNS update for a TESTED step: smooth the unbiased
    two-scale estimate, fit the slope of the smoothed B_simple, and emit the
    rung-crossing ETA + predicted target rung (DESIGN §14).  Returns the
    full predictor field dict — unchanged copies when cfg.predict is off —
    so both controller_update return paths can splat it."""
    fields = dict(gns_s=state.gns_s, gns_g2=state.gns_g2,
                  gns_init=state.gns_init, gns_b_prev=state.gns_b_prev,
                  gns_slope=state.gns_slope,
                  gns_slope_init=state.gns_slope_init,
                  pred_rung=state.pred_rung,
                  pred_eta_steps=state.pred_eta_steps)
    if not cfg.predict:
        return fields
    groups = variance_groups(
        "accum_norm" if cfg.gns_groups == "accum" else "fsdp_norm",
        state.plan.workers, state.plan.accum_steps)
    tracker = GNSTracker(cfg.gns_alpha, state.gns_s, state.gns_g2,
                         state.gns_init)
    tracker = tracker.update(var_l1, grad_sqnorm, state.plan.global_batch,
                             state.plan.workers, groups=groups)
    fields.update(gns_s=tracker.s_ema, gns_g2=tracker.g2_ema,
                  gns_init=tracker.initialized)
    if not tracker.initialized:
        return fields                 # estimate skipped (degenerate/clamped)
    b_now = tracker.b_simple
    if state.gns_init:                # gns_b_prev holds the previous B
        delta = b_now - state.gns_b_prev
        slope = (cfg.slope_alpha * state.gns_slope
                 + (1 - cfg.slope_alpha) * delta
                 if state.gns_slope_init else delta)   # seed, don't blend
        fields.update(gns_slope=slope, gns_slope_init=True)
    else:
        slope = 0.0
    fields["gns_b_prev"] = b_now
    b_k = state.plan.global_batch
    fields["pred_eta_steps"] = rung_crossing_eta(
        b_now, slope if fields["gns_slope_init"] else 0.0, b_k, cfg.eta,
        cfg.workers)
    rungs = ([min(p.global_batch, cfg.max_global_batch) for p in cfg.ladder
              if p.global_batch <= cfg.max_global_batch]
             if cfg.ladder else None)
    fields["pred_rung"] = predict_target_batch(
        b_now, slope if fields["gns_slope_init"] else 0.0,
        cfg.predict_horizon, b_k, cfg.eta, cfg.workers, rungs)
    return fields


def controller_update(cfg: ControllerConfig, state: ControllerState,
                      var_l1: float, grad_sqnorm: float) -> ControllerState:
    """One Algorithm-1 update after an optimizer step."""
    new_samples = state.samples + state.plan.global_batch
    step = state.step + 1

    # max-batch shortcut: the paper stops testing once b_k == max.  The
    # predictive companion still observes — the (var_l1, gsq) pair arrives
    # free with every step and the at_max latch would otherwise starve the
    # tracker exactly when the GNS trajectory becomes informative.  With
    # cfg.predict off, _predictor_fields returns unchanged copies and this
    # return is bit-identical to the pre-predictor controller.
    if state.at_max or (cfg.test_interval > 1 and step % cfg.test_interval != 0):
        pred = _predictor_fields(cfg, state, var_l1, grad_sqnorm)
        return replace(state, step=step, samples=new_samples, **pred)

    t_raw = norm_test_statistic(var_l1, grad_sqnorm, cfg.eta)
    if cfg.ema > 0:
        ema = cfg.ema * state.ema_stat + (1 - cfg.ema) * t_raw \
            if state.ema_init else t_raw
        t_eff = ema
    else:
        ema = t_raw
        t_eff = t_raw

    # predictive companion: pure observer of the same (var_l1, gsq) pair —
    # it steers warmup targeting, never the plan below
    pred = _predictor_fields(cfg, state, var_l1, grad_sqnorm)

    b_k = state.plan.global_batch
    if t_eff > b_k:
        desired = math.ceil(t_eff)
        if cfg.monotonic:
            desired = max(desired, b_k)
        plan = _resolve_plan(cfg, desired)
        if cfg.monotonic and plan.global_batch < b_k:
            plan = state.plan
        increased = plan.global_batch > b_k
        # the reachable ceiling: the largest ladder rung the cap permits —
        # a ladder whose top rung rounds below max_global_batch still
        # latches there (nothing larger is eligible)
        cap = cfg.max_global_batch
        if cfg.ladder:
            cap = max((p.global_batch for p in cfg.ladder
                       if p.global_batch <= cfg.max_global_batch),
                      default=cfg.ladder[0].global_batch)
        return ControllerState(
            plan=plan, step=step, samples=new_samples, ema_stat=ema,
            ema_init=True, last_T=t_raw,
            num_increases=state.num_increases + int(increased),
            at_max=plan.global_batch >= min(cfg.max_global_batch, cap),
            **pred)
    return replace(state, step=step, samples=new_samples, ema_stat=ema,
                   ema_init=True, last_T=t_raw, **pred)
