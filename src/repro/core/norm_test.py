"""The paper's core contribution: the (approximate) norm test, eq. (3)/(5).

Three estimators of the gradient-variance statistic ‖Var̂‖₁, all returning the
pair (var_l1, grad_sqnorm) from which the controller computes
T_k = var_l1 / (η² · grad_sqnorm)  and Algorithm 1's update b_{k+1} = ⌈T_k⌉:

* `per_sample_norm_test`   — eq. (3): exact per-sample gradients via vmap
                             (single-device / validation scale only; the paper
                             explains why this is impractical at LLM scale).
* `worker_variance_stats`  — eq. (5) DDP-/FSDP-Norm: variance of per-worker
                             minibatch gradients.  Lives inside the shard_map
                             manual region; collectives over the data axes.
* `accum_variance_stats`   — beyond-paper ACCUM-NORM: variance across the M
                             gradient-accumulation microbatch gradients, with
                             a (M-1)/M Bessel-style correction mapping it onto
                             the same per-minibatch scale as eq. (5).

All reductions are float32 regardless of gradient dtype.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp


def tree_sqnorm(tree) -> jax.Array:
    """Σ ‖x‖² over all leaves, in f32."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return functools.reduce(jnp.add, leaves, jnp.zeros((), jnp.float32))


def tree_sqdiff(tree_a, tree_b) -> jax.Array:
    """Σ ‖a − b‖² over all leaves, in f32 (reference impl; the Pallas
    `sqdiff_norm` kernel fuses this on TPU)."""
    la, lb = jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)
    acc = jnp.zeros((), jnp.float32)
    for a, b in zip(la, lb):
        acc += jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
    return acc


# ------------------------------------------------------- eq. (3) exact ----

def per_sample_norm_test(loss_fn, params, batch, eta: float):
    """Vanilla norm test (eq. 3) with exact per-sample gradients via vmap.

    loss_fn(params, single_example_batch) -> scalar.
    Returns dict(stat T, var_l1, grad_sqnorm, batch_grad).
    """
    b = jax.tree.leaves(batch)[0].shape[0]

    def one(example):
        return jax.grad(loss_fn)(params, example)

    per_sample = jax.vmap(one)(batch)                     # leaves: (b, ...)
    mean_grad = jax.tree.map(lambda g: jnp.mean(g, axis=0), per_sample)
    # ‖Var_i(∇ℓ_i)‖₁ = 1/(b-1) Σ_i ‖∇ℓ_i − ∇L_B‖²  (sum over coordinates)
    def var_leaf(ps, m):
        d = ps.astype(jnp.float32) - m.astype(jnp.float32)[None]
        return jnp.sum(jnp.square(d)) / max(b - 1, 1)
    var_l1 = functools.reduce(
        jnp.add,
        jax.tree.leaves(jax.tree.map(var_leaf, per_sample, mean_grad)),
        jnp.zeros((), jnp.float32))
    gsq = tree_sqnorm(mean_grad)
    stat = var_l1 / b / (eta**2 * gsq + 1e-30)
    return {"T": var_l1 / (eta**2 * gsq + 1e-30), "lhs_over_b": stat,
            "var_l1": var_l1, "grad_sqnorm": gsq, "grad": mean_grad}


# ------------------------------------------- eq. (5) DDP-/FSDP-Norm ----

def worker_variance_stats(local_grad, mean_grad, data_axes, *, sqdiff_fn=None):
    """Inside shard_map (manual over `data_axes`): per-worker statistic.

    local_grad : this worker's minibatch gradient g_j (model-axis sharded ok)
    mean_grad  : the pmean'd global gradient g
    Returns (var_l1, grad_sqnorm): ‖Var̂‖₁ = (1/J)Σ_j‖g_j − g‖² and ‖g‖².

    The local ‖g_j − g‖² is reduced to ONE f32 scalar before the collective —
    the beyond-paper wire-cost optimization (8 bytes vs O(d); DESIGN §7.1).
    """
    sqdiff = sqdiff_fn or tree_sqdiff
    local_sq = sqdiff(local_grad, mean_grad)              # scalar on this worker
    var_l1 = jax.lax.pmean(local_sq, data_axes)           # (1/J) Σ_j ‖g_j − g‖²
    gsq = tree_sqnorm(mean_grad)
    return var_l1, gsq


def worker_variance_stats_flat(local_grad, mean_grad, data_axes, *,
                               layout=None):
    """Flat-buffer variant of `worker_variance_stats` (DESIGN §9): both trees
    are packed into a few dtype-homogeneous buckets and the fused-stats
    kernel computes ‖g_j − g‖² AND ‖g‖² in ONE read of each bucket —
    replacing the sqdiff + sqnorm double pass with a single-pass pair.
    Same 8-byte pre-reduced collective as the tree path.

    `layout` is the step's shared `FlatLayout` (built once per step
    signature by the step builder); when omitted it is rebuilt here, at
    every trace.  Returns (var_l1, grad_sqnorm, mean_buffers) — the packed
    mean-gradient buffers go straight into `adamw_update_buffers`, so the
    mean gradient is packed exactly ONCE per step (the flat-tail
    double-pack regression, DESIGN §9)."""
    from repro.distributed.flatbuf import FlatLayout
    if layout is None:
        layout = FlatLayout.from_tree(mean_grad)
    local_b = layout.flatten(local_grad)
    mean_b = layout.flatten(mean_grad)
    var_l1, gsq = worker_variance_stats_buffers(local_b, mean_b, data_axes)
    return var_l1, gsq, mean_b


def worker_variance_stats_buffers(local_buffers, mean_buffers, data_axes):
    """Born-flat variant of `worker_variance_stats_flat` (DESIGN §10): the
    per-worker and mean gradients ALREADY live as bucketed flat buffers —
    flat-resident parameters differentiate w.r.t. the buffers, so autodiff
    emits gradient buffers directly and this path performs NO pack.  Shard
    padding is zero in every gradient buffer (the pad is never referenced
    by a slot, so its cotangent is the adjoint's zero fill) and contributes
    nothing to either sum.  Returns (var_l1, grad_sqnorm)."""
    from repro.kernels import ops
    local_sq = jnp.zeros((), jnp.float32)
    gsq = jnp.zeros((), jnp.float32)
    for lb, mb in zip(local_buffers, mean_buffers):
        d, q = ops.stats_flat(lb, mb)
        local_sq += d
        gsq += q
    var_l1 = jax.lax.pmean(local_sq, data_axes)
    return var_l1, gsq


def paper_faithful_worker_variance(local_grad, mean_grad, data_axes):
    """The paper's literal formulation: all-reduce the full (g_j − g)² vector
    (eq. 5 computes Var̂ as a d-vector, then takes ‖·‖₁).  Mathematically
    identical to `worker_variance_stats`; kept as the baseline for the §Perf
    collective-bytes comparison."""
    diff_sq = jax.tree.map(
        lambda a, b: jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)),
        local_grad, mean_grad)
    var_vec = jax.tree.map(lambda v: jax.lax.pmean(v, data_axes), diff_sq)
    var_l1 = tree_sqnorm(jax.tree.map(jnp.sqrt, var_vec))  # ‖Var̂‖₁ = Σ coords
    gsq = tree_sqnorm(mean_grad)
    return var_l1, gsq


# --------------------------------------------- beyond-paper ACCUM-NORM ----

def accum_variance_stats(micro_grads_sq_sum, mean_grad, num_micro: int,
                         workers: int, *, gsq=None):
    """Estimate the per-*minibatch* gradient variance from the M accumulation
    microbatch gradients (already data-axis averaged under GSPMD).

    Var across microbatches: V_m = (1/(M-1)) (Σ_m‖ĝ^m‖² − M‖g‖²) estimates
    tr Σ · M/(b/J · J) · ... — each microbatch has size b/M, so
    V_m ≈ tr(Σ_ps)·M/b.  The paper's eq.(5) statistic targets tr(Σ_ps)·J/b
    (per-worker minibatch size b/J), hence rescale by J/M.

    micro_grads_sq_sum : Σ_m ‖ĝ^m‖² (f32 scalar accumulated in the scan)
    mean_grad          : the averaged gradient g
    num_micro          : number of contributing microbatches — a static int,
                         or a traced count under the bucketed engine's padding
                         (fully-padded microbatches are excluded)
    gsq                : precomputed ‖g‖² (e.g. the flat AdamW kernel's
                         byproduct, DESIGN §9) — skips the tree_sqnorm pass
    """
    if gsq is None:
        gsq = tree_sqnorm(mean_grad)
    m = jnp.asarray(num_micro, jnp.float32)
    v_m = (micro_grads_sq_sum - m * gsq) / jnp.maximum(m - 1, 1.0)
    v_m = jnp.maximum(v_m, 0.0)
    # single microbatch -> no within-step variance signal
    var_l1 = jnp.where(m > 1, v_m * (workers / jnp.maximum(m, 1.0)), 0.0)
    return var_l1, gsq


# ----------------------------------------------------- exact variance ----

def exact_variance_test_holds(per_sample_grads, eta: float) -> jax.Array:
    """The exact-variance norm test (eq. 4) on materialized per-sample grads —
    used in unit tests to validate the estimators and Proposition 1's E-SG
    bound."""
    mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), per_sample_grads)
    b = jax.tree.leaves(per_sample_grads)[0].shape[0]

    def dev(ps, m):
        d = ps.astype(jnp.float32) - m.astype(jnp.float32)[None]
        return jnp.sum(jnp.square(d)) / b   # E‖g_B − ∇L‖² for b=1 draws / b
    lhs = functools.reduce(
        jnp.add, jax.tree.leaves(jax.tree.map(dev, per_sample_grads, mean)),
        jnp.zeros((), jnp.float32)) / b
    rhs = eta**2 * tree_sqnorm(mean)
    return lhs <= rhs
