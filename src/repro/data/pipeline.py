"""Data pipeline: deterministic synthetic token sources + a distributed
sampler that re-shards whenever the adaptive controller changes the
`BatchPlan` (the paper's dynamic-batch sampler, §3.2).

Sources
-------
* `UniformTokens`    — i.i.d. uniform tokens (throughput benchmarking).
* `MarkovTokens`     — a fixed random 1st-order Markov chain over the vocab;
                       has learnable structure so smoke-training losses
                       actually fall (stands in for C4 at CPU scale).
* `MemmapTokens`     — flat token file on disk (np.memmap), sequence-packed:
                       the production path (pre-tokenized corpus).

All sources are stateless w.r.t. the consumer: `batch(step, plan, seq_len)`
is a pure function of (seed, step, plan), so every worker can deterministically
materialize exactly its shard and re-sharding under a new BatchPlan is trivial
(this is how the PyTorch distributed sampler behaviour maps to JAX's
single-controller model).
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.schedule import BatchPlan


class TokenSource:
    vocab_size: int

    def sequences(self, step: int, count: int, seq_len: int) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass
class UniformTokens(TokenSource):
    vocab_size: int
    seed: int = 0

    def sequences(self, step, count, seq_len):
        rng = np.random.default_rng((self.seed, step))
        return rng.integers(0, self.vocab_size, (count, seq_len + 1), dtype=np.int32)


@dataclasses.dataclass
class MarkovTokens(TokenSource):
    """Sparse-ish random Markov chain; per-row transition supported on
    `fan_out` states => in-context predictable (val loss can approach
    log(fan_out) << log(vocab))."""
    vocab_size: int
    fan_out: int = 8
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._succ = rng.integers(0, self.vocab_size,
                                  (self.vocab_size, self.fan_out), dtype=np.int32)

    def sequences(self, step, count, seq_len):
        rng = np.random.default_rng((self.seed, 7919, step))
        out = np.empty((count, seq_len + 1), dtype=np.int32)
        state = rng.integers(0, self.vocab_size, count, dtype=np.int32)
        choices = rng.integers(0, self.fan_out, (count, seq_len + 1))
        for t in range(seq_len + 1):
            out[:, t] = state
            state = self._succ[state, choices[:, t]]
        return out


@dataclasses.dataclass
class MemmapTokens(TokenSource):
    """Pre-tokenized flat corpus; sequence-packed sampling WITH replacement
    (each draw is an independent uniform window start — there is no epoch
    bookkeeping, so short corpora revisit windows within what would be one
    epoch).  Requires at least `seq_len + 2` tokens: one window of
    `seq_len + 1` for the shifted next-token labels, plus one valid start."""
    path: str
    vocab_size: int
    seed: int = 0

    def __post_init__(self):
        try:
            self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        except ValueError as e:   # np.memmap refuses zero-length files
            raise ValueError(
                f"MemmapTokens corpus {self.path!r} is empty or unreadable "
                f"as int32 tokens: {e}") from e
        if len(self._data) == 0:
            raise ValueError(f"MemmapTokens corpus {self.path!r} is empty")

    def sequences(self, step, count, seq_len):
        n_tokens = len(self._data)
        n_starts = n_tokens - (seq_len + 1)
        if n_starts <= 0:
            raise ValueError(
                f"MemmapTokens corpus {self.path!r} has {n_tokens} tokens, "
                f"too short to sample a seq_len={seq_len} training window: "
                f"need at least {seq_len + 2} (seq_len + 1 tokens for the "
                "shifted next-token labels, plus one valid start)")
        rng = np.random.default_rng((self.seed, step))
        starts = rng.integers(0, n_starts, count)
        return np.stack([np.asarray(self._data[s : s + seq_len + 1]) for s in starts])


# ----------------------------------------------------------- sampler ----

def make_batch(source: TokenSource, step: int, plan: BatchPlan, seq_len: int,
               extra_specs=None):
    """Global stacked-microbatch batch for one optimizer step:
    tokens/labels of shape (M, J*micro, seq_len).  Re-sharding under a new
    plan is automatic — the layout is a pure function of the plan."""
    m, per_micro = plan.accum_steps, plan.workers * plan.micro_batch
    seqs = source.sequences(step, m * per_micro, seq_len)
    seqs = seqs.reshape(m, per_micro, seq_len + 1)
    batch = {
        "tokens": seqs[..., :-1],
        "labels": seqs[..., 1:].copy(),
    }
    if extra_specs:
        for name, shape_tail in extra_specs.items():
            # stable digest, NOT hash(): str hashes are PYTHONHASHSEED-
            # randomized per process, so hash(name) silently gave every
            # host a different extra-input batch — breaking this module's
            # "pure function of (seed, step, plan)" multi-host contract
            rng = np.random.default_rng((zlib.crc32(name.encode()), step))
            batch[name] = rng.standard_normal(
                (m, per_micro) + tuple(shape_tail)).astype(np.float32)
    return batch


def pad_to_bucket(batch, plan: BatchPlan, bucket: BatchPlan,
                  pad_token: int = 0):
    """Pad a stacked batch built for `plan` to `bucket`'s (M, B, ...) shape
    (the bucketed engine's shape quantization, DESIGN §8).

    The plan's real samples are laid row-major into the bucket's flattened
    (M*B) slots; the tail slots get `tokens = pad_token` and `labels = -1`,
    which the masked-mean, valid-token-weighted loss ignores exactly — padded
    and unpadded batches produce identical loss and gradients.  Extra
    frontend inputs (vision/audio stubs) pad with zeros.  Returns `batch`
    unchanged when it already has the bucket's shape.
    """
    m_b, per_b = bucket.accum_steps, bucket.workers * bucket.micro_batch
    m_r, per_r = plan.accum_steps, plan.workers * plan.micro_batch
    if (m_b, per_b) == (m_r, per_r):
        return batch
    n_real, cap = m_r * per_r, m_b * per_b
    assert cap >= n_real, (plan, bucket)
    out = {}
    for name, v in batch.items():
        tail = v.shape[2:]
        if name == "labels":
            flat = np.full((cap,) + tail, -1, dtype=v.dtype)
        elif name == "tokens":
            flat = np.full((cap,) + tail, pad_token, dtype=v.dtype)
        else:
            flat = np.zeros((cap,) + tail, dtype=v.dtype)
        flat[:n_real] = v.reshape((n_real,) + tail)
        out[name] = flat.reshape((m_b, per_b) + tail)
    return out


def microbatches(batch):
    """Iterate the M leading-axis microbatches of a stacked batch."""
    m = batch["tokens"].shape[0]
    for i in range(m):
        yield {k: v[i] for k, v in batch.items()}
