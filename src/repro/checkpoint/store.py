"""Checkpointing: pytree <-> npz with step metadata and atomic writes.

Host-based (gathers to host then writes); fine for the CPU container and the
paper's model sizes.  The tree is flattened to path-keyed arrays so restore
does not depend on Python object identity.
"""

from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, tree, metadata: dict | None = None):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    meta = {"step": step, **(metadata or {})}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f, indent=2, default=str)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree):
    """Restore into the structure of `like_tree` (shape/dtype validated)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten_with_paths(like_tree)
    restored_flat = {}
    for key, like in flat_like.items():
        arr = data[key]
        assert arr.shape == like.shape, (key, arr.shape, like.shape)
        restored_flat[key] = arr.astype(like.dtype)
    # rebuild in tree order
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(restored_flat[key])
    meta_path = os.path.join(directory, f"ckpt_{step:08d}.json")
    metadata = json.load(open(meta_path)) if os.path.exists(meta_path) else {}
    return jax.tree_util.tree_unflatten(treedef, leaves), metadata
