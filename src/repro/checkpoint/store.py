"""Checkpointing: pytree <-> npz with step metadata and atomic writes.

Host-based (gathers to host then writes); fine for the CPU container and the
paper's model sizes.  The tree is flattened to path-keyed arrays so restore
does not depend on Python object identity.

Flat-resident interop (DESIGN §10): a flat-resident job saves its raw param
bucket buffers (keys ``params/0..N``) plus the layout RECIPE in metadata
(``flat_params``: bucket_bytes + shard_divisor — `FlatLayout.from_tree` is
deterministic given those and the params structure).  `restore_params` /
`restore_params_flat` read a checkpoint of EITHER residency into the
caller's residency, bit-exactly, even across backends with different
default bucket sizes: the reader rebuilds the writer's layout from the
metadata, unflattens, and (for a flat reader) re-flattens at its own
layout — both hops are exact slices/concats.
"""

from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, tree, metadata: dict | None = None):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    meta = {"step": step, **(metadata or {})}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f, indent=2, default=str)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree):
    """Restore into the structure of `like_tree` (shape/dtype validated)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat_like = _flatten_with_paths(like_tree)
    restored_flat = {}
    for key, like in flat_like.items():
        arr = data[key]
        assert arr.shape == like.shape, (key, arr.shape, like.shape)
        restored_flat[key] = arr.astype(like.dtype)
    # rebuild in tree order
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(restored_flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves), _read_meta(
        directory, step)


# ------------------------------------------- flat-resident interop ----

FLAT_PARAMS_META = "flat_params"


def flat_params_metadata(layout) -> dict:
    """The layout recipe a reader needs to rebuild the EXACT `FlatLayout`
    of a flat-resident params checkpoint: `FlatLayout.from_tree` is
    deterministic given the params structure plus these two knobs."""
    return {"bucket_bytes": layout.bucket_bytes,
            "shard_divisor": layout.shard_divisor}


def _read_meta(directory: str, step: int) -> dict:
    meta_path = os.path.join(directory, f"ckpt_{step:08d}.json")
    return json.load(open(meta_path)) if os.path.exists(meta_path) else {}


def restore_params(directory: str, step: int, params_like):
    """The checkpoint's ``params`` entry as a pytree shaped like
    `params_like`, whatever residency it was saved in (bit-exact).

    Tree-resident checkpoints restore leaf-by-leaf (leaves cast to the
    reader's dtypes, like `restore_checkpoint`); flat-resident ones
    (metadata carries ``flat_params``) rebuild the writer's layout from
    `params_like` and unflatten the raw bucket buffers — there the
    reader's dtypes must MATCH the checkpoint's (buffer bucketing is
    dtype-grouped, so a cross-dtype flat restore has no well-defined
    layout; the dtype check below turns that into a loud error instead of
    a silently mis-grouped tree).  Returns (tree, metadata)."""
    metadata = _read_meta(directory, step)
    fl = metadata.get(FLAT_PARAMS_META)
    if fl:
        from repro.distributed.flatbuf import FlatLayout
        path = os.path.join(directory, f"ckpt_{step:08d}.npz")
        data = np.load(path)
        layout = FlatLayout.from_tree(
            params_like, bucket_bytes=int(fl["bucket_bytes"]),
            shard_divisor=int(fl["shard_divisor"]))
        buffers = []
        for i, (size, dt) in enumerate(zip(layout.buffer_sizes,
                                           layout.buffer_dtypes)):
            arr = data[f"params/{i}"]
            assert arr.shape == (size,), (i, arr.shape, size)
            assert arr.dtype == dt, (
                f"buffer {i}: checkpoint dtype {arr.dtype} != reader's "
                f"layout dtype {dt} — flat-resident restore requires "
                f"matching param dtypes")
            buffers.append(arr)
        return layout.unflatten(buffers), metadata
    # tree-resident: delegate to the standard leaf-keyed restore on the
    # params subtree (one implementation of the key format and the
    # shape/dtype handling)
    tree, metadata = restore_checkpoint(directory, step,
                                        {"params": params_like})
    return tree["params"], metadata


def restore_params_flat(directory: str, step: int, params_like, *,
                        bucket_bytes: int | None = None,
                        shard_divisor: int = 1):
    """`FlatParams` at the CALLER's layout (its backend's bucket size / its
    mesh's worker count) from a checkpoint of either residency — the
    unflatten-via-writer-layout → flatten-via-reader-layout round trip is
    bit-exact.  Returns (FlatParams, metadata)."""
    from repro.distributed.flatbuf import FlatParams
    tree, metadata = restore_params(directory, step, params_like)
    return (FlatParams.from_tree(tree, bucket_bytes=bucket_bytes,
                                 shard_divisor=shard_divisor), metadata)
