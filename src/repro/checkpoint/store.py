"""Checkpointing: pytree <-> npz with step metadata and atomic writes.

Host-based (gathers to host then writes); fine for the CPU container and the
paper's model sizes.  The tree is flattened to path-keyed arrays so restore
does not depend on Python object identity.

Flat-resident interop (DESIGN §10): a flat-resident job saves its raw param
bucket buffers (keys ``params/0..N``) plus the layout RECIPE in metadata
(``flat_params``: bucket_bytes + shard_divisor — `FlatLayout.from_tree` is
deterministic given those and the params structure).  `restore_params` /
`restore_params_flat` read a checkpoint of EITHER residency into the
caller's residency, bit-exactly, even across backends with different
default bucket sizes: the reader rebuilds the writer's layout from the
metadata, unflattens, and (for a flat reader) re-flattens at its own
layout — both hops are exact slices/concats.

Crash atomicity (DESIGN §12): both files of a checkpoint are written to
temp names and `os.replace`d, json FIRST — `latest_step` keys on the npz,
so the only states a crash at any instant can leave are (a) temp litter a
later save cleans up, (b) a json without its npz (invisible to
`latest_step`), or (c) a complete npz+json pair.  A torn or unreadable
checkpoint surfaces as a typed `CheckpointError` naming the file, never a
partial silent restore.  One writer per directory is assumed (the train
driver's `checkpoint_dir` is per-host).
"""

from __future__ import annotations

import contextlib
import json
import os

import jax
import numpy as np

from repro.testing.faults import fault_point


class CheckpointError(RuntimeError):
    """A checkpoint file is missing, torn, or inconsistent with the reader's
    expected structure — restore refuses to proceed partially."""


def _flatten_with_paths(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _clean_stale_tmp(directory: str) -> None:
    """Drop temp litter a crashed writer left behind (single-writer dirs)."""
    for f in os.listdir(directory):
        if f.startswith("ckpt_") and ".tmp" in f:
            with contextlib.suppress(OSError):
                os.remove(os.path.join(directory, f))


def save_checkpoint(directory: str, step: int, tree, metadata: dict | None = None):
    os.makedirs(directory, exist_ok=True)
    _clean_stale_tmp(directory)
    flat = _flatten_with_paths(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    meta_path = os.path.join(directory, f"ckpt_{step:08d}.json")
    tmp_npz = f"{path}.tmp{os.getpid()}"
    tmp_json = f"{meta_path}.tmp{os.getpid()}"
    with open(tmp_npz, "wb") as f:       # a file OBJECT: savez appends no suffix
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    meta = {"step": step, **(metadata or {})}
    with open(tmp_json, "w") as f:
        json.dump(meta, f, indent=2, default=str)
        f.flush()
        os.fsync(f.fileno())
    fault_point("ckpt.save.before_commit", path=path)
    # json first: the npz's visibility implies its metadata already exists,
    # so `latest_step` (npz-keyed) only ever names complete pairs
    os.replace(tmp_json, meta_path)
    os.replace(tmp_npz, path)
    fault_point("ckpt.saved", path=path)
    return path


def latest_step(directory: str) -> int | None:
    """The newest step with a COMPLETE npz+json pair (a crash mid-save can
    leave temp litter or a lone json; neither is restorable)."""
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")
             and ".tmp" not in f
             and os.path.exists(os.path.join(directory, f[:-4] + ".json"))]
    return max(steps) if steps else None


def _open_npz(path: str):
    if not os.path.exists(path):
        raise CheckpointError(f"checkpoint {path} does not exist")
    try:
        return np.load(path)
    except Exception as e:   # zipfile.BadZipFile, OSError, ValueError...
        raise CheckpointError(
            f"checkpoint {path} is unreadable (truncated or corrupt): "
            f"{e}") from e


def _get_array(data, key: str, path: str):
    try:
        return data[key]
    except KeyError:
        raise CheckpointError(
            f"checkpoint {path} has no entry {key!r} — it was saved from a "
            "different state structure than the reader's") from None
    except Exception as e:   # torn member: zlib/zipfile error mid-extract
        raise CheckpointError(
            f"checkpoint {path} entry {key!r} is torn or corrupt: {e}") from e


def restore_checkpoint(directory: str, step: int, like_tree):
    """Restore into the structure of `like_tree` (shape/dtype validated);
    any torn file / missing entry / shape mismatch is a `CheckpointError`,
    never a partial restore."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = _open_npz(path)
    flat_like = _flatten_with_paths(like_tree)
    restored_flat = {}
    for key, like in flat_like.items():
        arr = _get_array(data, key, path)
        if arr.shape != like.shape:
            raise CheckpointError(
                f"checkpoint {path} entry {key!r} has shape {arr.shape}, "
                f"reader expects {like.shape}")
        restored_flat[key] = arr.astype(like.dtype)
    # rebuild in tree order
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path_, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        leaves.append(restored_flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves), _read_meta(
        directory, step)


# ------------------------------------------- flat-resident interop ----

FLAT_PARAMS_META = "flat_params"


def flat_params_metadata(layout) -> dict:
    """The layout recipe a reader needs to rebuild the EXACT `FlatLayout`
    of a flat-resident params checkpoint: `FlatLayout.from_tree` is
    deterministic given the params structure plus these two knobs."""
    return {"bucket_bytes": layout.bucket_bytes,
            "shard_divisor": layout.shard_divisor}


def _read_meta(directory: str, step: int) -> dict:
    meta_path = os.path.join(directory, f"ckpt_{step:08d}.json")
    return json.load(open(meta_path)) if os.path.exists(meta_path) else {}


def restore_params(directory: str, step: int, params_like):
    """The checkpoint's ``params`` entry as a pytree shaped like
    `params_like`, whatever residency it was saved in (bit-exact).

    Tree-resident checkpoints restore leaf-by-leaf (leaves cast to the
    reader's dtypes, like `restore_checkpoint`); flat-resident ones
    (metadata carries ``flat_params``) rebuild the writer's layout from
    `params_like` and unflatten the raw bucket buffers — there the
    reader's dtypes must MATCH the checkpoint's (buffer bucketing is
    dtype-grouped, so a cross-dtype flat restore has no well-defined
    layout; the dtype check below turns that into a loud error instead of
    a silently mis-grouped tree).  Returns (tree, metadata)."""
    metadata = _read_meta(directory, step)
    fl = metadata.get(FLAT_PARAMS_META)
    if fl:
        from repro.distributed.flatbuf import FlatLayout
        path = os.path.join(directory, f"ckpt_{step:08d}.npz")
        data = _open_npz(path)
        layout = FlatLayout.from_tree(
            params_like, bucket_bytes=int(fl["bucket_bytes"]),
            shard_divisor=int(fl["shard_divisor"]))
        buffers = []
        for i, (size, dt) in enumerate(zip(layout.buffer_sizes,
                                           layout.buffer_dtypes)):
            arr = _get_array(data, f"params/{i}", path)
            if arr.shape != (size,):
                raise CheckpointError(
                    f"checkpoint {path} params buffer {i} has shape "
                    f"{arr.shape}, writer's layout says ({size},)")
            if arr.dtype != dt:
                raise CheckpointError(
                    f"buffer {i}: checkpoint dtype {arr.dtype} != reader's "
                    f"layout dtype {dt} — flat-resident restore requires "
                    f"matching param dtypes")
            buffers.append(arr)
        return layout.unflatten(buffers), metadata
    # tree-resident: delegate to the standard leaf-keyed restore on the
    # params subtree (one implementation of the key format and the
    # shape/dtype handling)
    tree, metadata = restore_checkpoint(directory, step,
                                        {"params": params_like})
    return tree["params"], metadata


def restore_params_flat(directory: str, step: int, params_like, *,
                        bucket_bytes: int | None = None,
                        shard_divisor: int = 1):
    """`FlatParams` at the CALLER's layout (its backend's bucket size / its
    mesh's worker count) from a checkpoint of either residency — the
    unflatten-via-writer-layout → flatten-via-reader-layout round trip is
    bit-exact.  Returns (FlatParams, metadata)."""
    from repro.distributed.flatbuf import FlatParams
    tree, metadata = restore_params(directory, step, params_like)
    return (FlatParams.from_tree(tree, bucket_bytes=bucket_bytes,
                                 shard_divisor=shard_divisor), metadata)
