"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds (DESIGN / task spec):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = wire_bytes_per_device / ICI_link_bandwidth

`cost_analysis()` of an SPMD-partitioned module reports the per-device
program, so FLOPs/bytes are already per-chip.  Collective bytes are parsed
from the optimized HLO text with per-op wire-cost factors (ring algorithms,
(n−1)/n ≈ 1):

    all-reduce          2 × result bytes   (reduce-scatter + all-gather)
    all-gather          1 × result bytes
    reduce-scatter      1 × operand bytes
    all-to-all          1 × result bytes
    collective-permute  1 × result bytes

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum wire bytes per collective kind from optimized HLO text."""
    out = {op: {"count": 0, "result_bytes": 0, "operand_bytes": 0}
           for op in _COLLECTIVE_OPS}
    # lines look like:  %name = TYPE op-name(%arg, ...), channel_id=...
    line_re = re.compile(
        r"=\s+((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(([^)]*)\)")
    for m in line_re.finditer(hlo_text):
        result_type, op, args = m.group(1), m.group(2), m.group(3)
        out[op]["count"] += 1
        out[op]["result_bytes"] += _shape_bytes(result_type)
        out[op]["operand_bytes"] += _shape_bytes(args)
    return out


def wire_bytes(collectives: dict) -> float:
    b = 0.0
    b += 2.0 * collectives["all-reduce"]["result_bytes"]
    b += 1.0 * collectives["all-gather"]["result_bytes"]
    b += 1.0 * collectives["reduce-scatter"]["operand_bytes"]
    b += 1.0 * collectives["all-to-all"]["result_bytes"]
    b += 1.0 * collectives["collective-permute"]["result_bytes"]
    return b


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def as_dict(self):
        return self.__dict__.copy()


def roofline_terms(cost_analysis: dict, hlo_text: str,
                   model_flops_per_device: float = 0.0) -> Roofline:
    flops = float(cost_analysis.get("flops", 0.0))
    hbm = float(cost_analysis.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    wb = wire_bytes(coll)
    c = flops / PEAK_FLOPS
    m = hbm / HBM_BW
    k = wb / ICI_BW
    terms = {"compute": c, "memory": m, "collective": k}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_per_device / flops if flops > 0 else 0.0
    return Roofline(flops=flops, hbm_bytes=hbm, wire_bytes=wb,
                    compute_s=c, memory_s=m, collective_s=k,
                    bottleneck=bottleneck,
                    model_flops=model_flops_per_device, useful_ratio=useful)


def model_flops_per_step(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per device.

    For train: D = global_batch × seq tokens, factor 6 (fwd 2 + bwd 4).
    For prefill: factor 2. For decode: one token per sequence, factor 2."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:
        tokens = shape.global_batch
        factor = 2.0
    return factor * n_active * tokens / n_devices
