"""Production mesh builders.

`make_production_mesh` is a FUNCTION (not a module constant) so importing this
module never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

from repro.compat import make_mesh, set_mesh  # noqa: F401  (set_mesh re-export)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod slice: (16,16) = 256 chips single pod; (2,16,16) = 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (CPU tests)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel (norm-test worker) axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_workers(mesh) -> int:
    J = 1
    for a in data_axes(mesh):
        J *= mesh.shape[a]
    return J
