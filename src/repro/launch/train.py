"""Training driver: adaptive / constant / stagewise batch-size pretraining.

Usable as a library (`run_training(TrainJob(...))` — benchmarks and examples
call this) and as a CLI:

    PYTHONPATH=src python -m repro.launch.train \
        --arch microllama-300m --smoke --schedule adaptive --eta 0.2 \
        --steps 200 --seq-len 128 --max-global-batch 256

The loop is Algorithm 1: for each step the controller's BatchPlan determines
the (M, J*micro, seq) stacked batch; the fused distributed step accumulates
over M, runs the norm test collectives and the AdamW update; the host
controller consumes (var_l1, grad_sqnorm) and emits the next plan.  A new
(M, micro) pair compiles once and is cached.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time
import zlib
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.controller import (
    ControllerConfig, controller_state_as_dict, controller_state_from_dict,
    init_controller, controller_update)
from repro.core.schedule import (
    BatchPlan, ConstantSchedule, StagewiseSchedule, accum_free_plan,
    bucket_ladder, parse_ladder, round_plan)
from repro.data.pipeline import (
    MarkovTokens, UniformTokens, make_batch, pad_to_bucket)
from repro.distributed.coordination import (
    CoordinationError, enable_persistent_cache, make_coordinator)
from repro.distributed.engine import BucketedEngine
from repro.distributed.train_step import make_fsdp_norm_step, make_accum_norm_step
from repro.compat import set_mesh
from repro.launch.mesh import make_host_mesh, num_workers
from repro.models import build_model
from repro.optim.adamw import (
    AdamWConfig, init_adamw, init_adamw_flat, warmup_cosine)
from repro.checkpoint.store import (
    FLAT_PARAMS_META, flat_params_metadata, latest_step, restore_checkpoint,
    save_checkpoint)
from repro.testing.faults import fault_point


@dataclass
class TrainJob:
    arch: str = "microllama-300m"
    smoke: bool = True
    schedule: str = "adaptive"            # adaptive | constant | stagewise
    step_impl: str = "fsdp_norm"          # fsdp_norm | accum_norm
    variance_impl: str = "scalar"         # scalar | paper
    stats_impl: str = "tree"              # tree | flat (DESIGN §9 buffers)
    params_impl: str = "tree"             # tree | flat (DESIGN §10 resident)
    eta: float = 0.2
    steps: int = 200
    total_samples: int | None = None      # stop criterion (paper trains by samples)
    seq_len: int = 128
    base_global_batch: int = 16
    max_global_batch: int = 256
    base_micro_batch: int = 2
    max_micro_batch: int = 4
    base_accum: int = 2
    test_interval: int = 1
    ema: float = 0.0
    # predictive GNS companion (DESIGN §14): fit the smoothed B_simple
    # trajectory and AOT-warm the PREDICTED target rung instead of blindly
    # the next one.  Pure observer — the batch trajectory is identical with
    # predict on or off.
    predict: bool = False
    gns_alpha: float = 0.9
    slope_alpha: float = 0.5
    predict_horizon: int = 5
    # accumulation-free low rungs (DESIGN §14; Marek et al.): re-plan rungs
    # with global batch <= accum_free_below as M=1 plans run `M` times —
    # same samples per scheduled step, proportionally more optimizer steps.
    # accum_free_below=0 means auto (workers * max_micro_batch).
    accum_free: bool = False
    accum_free_below: int = 0
    stages: tuple = ((0.025, 16), (0.025, 64), (0.95, 256))
    peak_lr: float = 4e-4
    min_lr: float = 4e-5
    warmup_frac: float = 0.01
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    data: str = "markov"                  # markov | uniform
    data_seed: int = 0
    seed: int = 0
    mesh_data: int = 0                    # 0 => all devices on data axis
    mesh_model: int = 1
    # sequence-length warmup (paper §2; GrowLength/Llama-3 style): stages of
    # (fraction_of_samples, seq_len); empty = constant job.seq_len
    seq_stages: tuple = ()
    # bucketed step-compilation engine (DESIGN §8): 'auto' builds the
    # powers-of-two ladder from the batch knobs; 'off' recompiles per plan
    # (the pre-engine behavior); or an explicit 'micro:accum,micro:accum,...'
    bucket_ladder: str = "auto"
    aot_warmup: bool = False              # compile the next rung in background
    # multi-host warmup coordination (DESIGN §8.1): 'none' = uncoordinated
    # single-host engine (bit-identical to no coordination); 'file' = shared
    # directory (subprocess tests, NFS fleets); 'distributed' = jax.distributed
    coord: str = "none"                   # none | file | distributed
    coord_dir: str = ""                   # shared dir for --coord=file
    coord_rank: int = -1                  # -1: resolve from REPRO_COORD_RANK
    coord_world: int = 0                  # 0: resolve from REPRO_COORD_WORLD
    coord_timeout: float = 120.0          # barrier/agreement timeout seconds
                                          # (file coord; 'distributed' uses
                                          # the jax.distributed runtime's own
                                          # collective timeouts)
    # persistent XLA compile cache dir (keyed per jax version + backend):
    # restarted / late-joining workers deserialize executables from disk
    compile_cache: str = ""
    eval_every: int = 25
    eval_batches: int = 4
    checkpoint_dir: str = ""
    # crash-safe training (DESIGN §12): checkpoint_every > 0 writes a
    # crash-atomic checkpoint (params/opt + controller state + samples
    # cursor) every N steps; --resume restarts from the newest complete
    # checkpoint in checkpoint_dir and reproduces the uninterrupted run's
    # losses BIT-identically (data/eval/LR are pure functions of the
    # restored step/samples cursors)
    checkpoint_every: int = 0
    resume: bool = False
    log_path: str = ""


def _make_source(job: TrainJob, vocab: int):
    if job.data == "markov":
        return MarkovTokens(vocab_size=vocab, seed=job.data_seed)
    return UniformTokens(vocab_size=vocab, seed=job.data_seed)


def _sds(batch):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)


def run_training(job: TrainJob) -> dict:
    if job.compile_cache:
        # before any compile: every executable this job builds lands in (or
        # comes from) the per-job persistent cache
        enable_persistent_cache(job.compile_cache)
    # run identity for the file coordinator: a digest of the job config
    # minus per-host fields, so every rank of THIS job (including restarts)
    # shares one coordination namespace while a different job pointed at a
    # reused --coord-dir can never replay this run's barrier/agreement state.
    # `resume` is excluded too: a crashed worker restarted with --resume is
    # the SAME run and must land in the same namespace — barrier files it
    # re-crosses while replaying its deterministic prefix already exist
    # there (the FileCoordinator restart contract)
    per_host = {"coord_rank", "log_path", "checkpoint_dir", "resume"}
    run_id = "job-%08x" % zlib.crc32(repr(sorted(
        (k, v) for k, v in dataclasses.asdict(job).items()
        if k not in per_host)).encode())
    coordinator = make_coordinator(job.coord, root=job.coord_dir,
                                   rank=job.coord_rank, world=job.coord_world,
                                   timeout=job.coord_timeout, run_id=run_id)
    cfg = get_smoke_config(job.arch) if job.smoke else get_config(job.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(job.seed)
    params = model.init(key)

    n_dev = len(jax.devices())
    d = job.mesh_data or max(1, n_dev // job.mesh_model)
    mesh = make_host_mesh(data=d, model=job.mesh_model)
    workers = num_workers(mesh)

    opt_cfg = AdamWConfig(lr=job.peak_lr, weight_decay=job.weight_decay,
                          grad_clip=job.grad_clip)
    if job.step_impl == "fsdp_norm":
        wrap, _, _ = make_fsdp_norm_step(model, opt_cfg, mesh,
                                         variance_impl=job.variance_impl,
                                         stats_impl=job.stats_impl,
                                         params_impl=job.params_impl,
                                         params_like=params)
    else:
        wrap, _, _ = make_accum_norm_step(model, opt_cfg, mesh,
                                          stats_impl=job.stats_impl,
                                          params_impl=job.params_impl,
                                          params_like=params)
    # the ONE per-step-signature layout the builder compiled against —
    # shared with the optimizer state, the residency conversion, and the
    # checkpoint metadata (None on the pure tree path)
    layout = wrap.flat_layout
    # flat moment buckets are padded to J-divisible sizes and SHARDED over
    # the data axes (DESIGN §9) — the state layout must match the step's
    opt_state = (init_adamw_flat(params, shard_divisor=workers, layout=layout)
                 if job.stats_impl == "flat" else init_adamw(params))
    if job.params_impl == "flat":
        # flat residency (DESIGN §10): the ONLY pack of the whole run —
        # from here on gradients are born flat and params stay buffers
        params = tuple(layout.flatten(params))

    if job.bucket_ladder == "off":
        ladder = None
    elif job.bucket_ladder == "auto":
        # the ladder must cover every plan any schedule can emit, including
        # stagewise stages configured above max_global_batch
        top = max(job.max_global_batch, job.base_global_batch,
                  *([b for _, b in job.stages] if job.schedule == "stagewise"
                    else [0]))
        ladder = bucket_ladder(workers, job.base_micro_batch,
                               job.max_micro_batch, job.base_accum,
                               min(job.base_global_batch, top), top)
    else:
        ladder = parse_ladder(job.bucket_ladder, workers)

    # accum-free low rungs need their (M=1, J·mb) shapes ON the ladder or
    # the engine rejects them with LadderShapeError.  APPEND the extra rungs:
    # quantize_to_ladder's sort is stable, so on a capacity tie the original
    # accumulated rung still wins for normal plan quantization and the
    # accum-free branch selects its M=1 rung explicitly.
    accum_free_below = job.accum_free_below or workers * job.max_micro_batch
    if job.accum_free and ladder is not None:
        have = {(p.accum_steps, p.micro_batch) for p in ladder}
        extra = []
        for mb in sorted({p.micro_batch for p in ladder}):
            if (1, mb) not in have:
                extra.append(BatchPlan(global_batch=workers * mb,
                                       micro_batch=mb, accum_steps=1,
                                       workers=workers))
                have.add((1, mb))
        ladder = ladder + tuple(extra)

    ctrl_cfg = ControllerConfig(
        eta=job.eta, workers=workers,
        base_micro_batch=job.base_micro_batch,
        max_micro_batch=job.max_micro_batch, base_accum=job.base_accum,
        base_global_batch=job.base_global_batch,
        max_global_batch=job.max_global_batch,
        test_interval=job.test_interval, ema=job.ema, ladder=ladder,
        predict=job.predict, gns_alpha=job.gns_alpha,
        gns_groups="accum" if job.step_impl == "accum_norm" else "workers",
        slope_alpha=job.slope_alpha, predict_horizon=job.predict_horizon)
    ctrl = init_controller(ctrl_cfg)

    if job.schedule == "constant":
        schedule = ConstantSchedule(round_plan(
            job.base_global_batch, workers, job.base_micro_batch,
            job.max_micro_batch, job.base_accum, job.base_global_batch))
    elif job.schedule == "stagewise":
        schedule = StagewiseSchedule(tuple(job.stages), workers,
                                     job.base_micro_batch, job.max_micro_batch,
                                     job.base_accum, ladder=ladder)
    else:
        schedule = None

    total_samples = job.total_samples or job.steps * job.max_global_batch
    # the paper schedules the lr in SAMPLES (Table 5: warmup 1% of training
    # samples) — the only fair basis when batch sizes differ across schemes
    warmup_samples = max(1, int(job.warmup_frac * total_samples))

    source = _make_source(job, cfg.vocab_size)
    # held-out evaluation: same distribution (same Markov chain), disjoint
    # step-id stream => unseen sequences
    val_source = source
    VAL_STEP_BASE = 1_000_000_000

    extra_specs = {}
    if cfg.frontend.kind == "vision_stub":
        extra_specs["patch_embeds"] = (cfg.frontend.num_prefix_tokens, cfg.d_model)
    elif cfg.frontend.kind == "audio_stub":
        extra_specs["frames"] = (cfg.encoder.num_frames, cfg.d_model)

    compiled = {}
    eval_fn = {}

    engine = None
    if ladder is not None:
        engine = BucketedEngine(wrap, ladder, mesh=mesh,
                                params_like=_sds(params),
                                opt_like=_sds(opt_state),
                                aot_warmup=job.aot_warmup,
                                coordinator=coordinator)

    def get_step(plan: BatchPlan, batch):
        # legacy path (bucket_ladder='off'): one compile per (M, micro, seq)
        key_ = (plan.accum_steps, plan.micro_batch,
                batch["tokens"].shape[-1])
        if key_ not in compiled:
            compiled[key_] = wrap(_sds(batch))
        return compiled[key_]

    def eval_loss(params, step):
        bplan = BatchPlan(global_batch=workers * 2, micro_batch=2,
                          accum_steps=1, workers=workers)
        losses = []
        for i in range(job.eval_batches):
            vb = make_batch(val_source, VAL_STEP_BASE + i, bplan,
                            job.seq_len, extra_specs)
            vb = {k: jnp.asarray(v[0]) for k, v in vb.items()}
            if "eval" not in eval_fn:
                if job.params_impl == "flat":
                    # unflatten INSIDE the jit: the tree view is sliced out
                    # of the resident buffers, never materialized on host
                    eval_fn["eval"] = jax.jit(
                        lambda pb, b: model.loss(layout.unflatten(list(pb)),
                                                 b)[0])
                else:
                    eval_fn["eval"] = jax.jit(lambda p, b: model.loss(p, b)[0])
            losses.append(float(eval_fn["eval"](params, vb)))
        return float(np.mean(losses))

    history = {"step": [], "loss": [], "val_loss": [], "global_batch": [],
               "T": [], "var_l1": [], "grad_sqnorm": [], "samples": [],
               "time": [], "accum_steps": [], "opt_steps": [],
               "pred_rung": [], "pred_eta": []}
    history["workers"] = workers
    samples = 0
    step = 0

    # ------------------------------------------------- crash-safe resume --
    # Restore the FULL loop state: params/opt (in this job's residency —
    # the like-tree was just built in it), the controller state machine,
    # and the step/samples cursors.  Everything else the loop consumes —
    # batches, eval batches, the LR — is a pure function of those cursors,
    # so the resumed trajectory is bit-identical to the uninterrupted one.
    resumed_from = None
    if job.resume:
        if not job.checkpoint_dir:
            raise ValueError("--resume requires --checkpoint-dir")
        ck = latest_step(job.checkpoint_dir)
        if ck is not None:
            state, meta = restore_checkpoint(
                job.checkpoint_dir, ck, {"params": params, "opt": opt_state})
            saved_job = meta.get("job", {})
            for f in ("arch", "step_impl", "stats_impl", "params_impl",
                      "schedule", "seed", "data_seed"):
                want, got = str(getattr(job, f)), str(saved_job.get(
                    f, getattr(job, f)))
                if got != want:
                    raise ValueError(
                        f"--resume config mismatch on {f!r}: checkpoint was "
                        f"saved with {got}, this job has {want}")
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt"])
            step = ck
            samples = int(meta.get("samples", 0))
            if "controller" in meta:
                ctrl = controller_state_from_dict(meta["controller"])
            resumed_from = ck
    history["resumed_from"] = resumed_from

    last_saved = [-1]

    def save_state():
        """Crash-atomic full-state checkpoint at the CURRENT step (no-op
        without a checkpoint_dir, or when this step is already on disk)."""
        if not job.checkpoint_dir or last_saved[0] == step:
            return
        meta = {"job": dataclasses.asdict(job), "samples": samples,
                "controller": controller_state_as_dict(ctrl)}
        if job.stats_impl == "flat":
            # flat moments are raw bucketed buffers: record the STEP'S OWN
            # layout recipe (bucket size + worker count) — a reader on a
            # different backend/mesh must rebuild the SAME FlatLayout to
            # unflatten them
            meta["flat_layout"] = flat_params_metadata(layout)
        if job.params_impl == "flat":
            # flat-RESIDENT params save as raw buffers (params/0..N); the
            # recipe lets any reader — tree-resident, or flat on another
            # backend's bucket size — rebuild this exact layout and restore
            # bit-exactly (checkpoint.store.restore_params[_flat])
            meta[FLAT_PARAMS_META] = flat_params_metadata(layout)
        save_checkpoint(job.checkpoint_dir, step,
                        {"params": params, "opt": opt_state}, metadata=meta)
        last_saved[0] = step

    t0 = time.time()
    log_f = (open(job.log_path, "a" if resumed_from is not None else "w")
             if job.log_path else None)
    if log_f and resumed_from is None:
        log_f.write("step,samples,global_batch,accum,micro,loss,val_loss,T,var_l1,grad_sqnorm,wall_s\n")

    def seq_len_for(samples_done: int) -> int:
        if not job.seq_stages:
            return job.seq_len
        frac = samples_done / max(total_samples, 1)
        acc = 0.0
        for f, sl in job.seq_stages:
            acc += f
            if frac < acc:
                return sl
        return job.seq_stages[-1][1]

    try:
        with set_mesh(mesh):
            while samples < total_samples and step < job.steps:
                # injection site: the Nth call is the Nth step of the RUN,
                # not of this process — chaos tests key kill rules on it
                fault_point("train.step", step=step + 1)
                if schedule is not None:
                    plan = schedule.plan_for(samples, total_samples)
                else:
                    plan = ctrl.plan
                seq_len = seq_len_for(samples)
                batch_np = make_batch(source, step, plan, seq_len, extra_specs)
                bucket = None
                if engine is not None:
                    # no max_global clamp here: the ladder top is built to
                    # cover every schedule plan, including stagewise stages
                    # configured above max_global_batch (the controller
                    # clamps its own plans)
                    bucket = engine.bucket_for(plan.global_batch)

                # accum-free low rungs (DESIGN §14): re-plan this scheduled
                # step as M optimizer steps of the same (J·mb) microbatch.
                # Guards: the plan must BE its rung (a padded bucket could
                # leave an all-padding sub-step whose zero gradient still
                # weight-decays — not equivalent), and on a TESTED adaptive
                # step the M=1 sub-plan must still carry live variance
                # signal (FSDP-Norm with J>1 compares worker gradients;
                # ACCUM-NORM's M=1 variance is identically zero and would
                # kill the controller) — otherwise keep the accumulated
                # path for that step.
                tested = (job.schedule == "adaptive" and not ctrl.at_max
                          and (ctrl_cfg.test_interval <= 1
                               or (ctrl.step + 1) % ctrl_cfg.test_interval == 0))
                signal_alive = job.step_impl == "fsdp_norm" and workers > 1
                use_af = (job.accum_free and plan.accum_steps > 1
                          and plan.global_batch <= accum_free_below
                          and (bucket is None or bucket == plan)
                          and (job.schedule != "adaptive" or not tested
                               or signal_alive))

                if use_af:
                    sub_plan, repeats = accum_free_plan(plan)
                    sub_losses = []
                    for m in range(repeats):
                        sub_np = {k: v[m:m + 1] for k, v in batch_np.items()}
                        if engine is not None:
                            # (1, J·mb) is on the ladder by construction
                            # (the accum-free rungs appended above)
                            step_fn = engine.get_step(sub_np)
                            engine.observe(sub_plan, sub_plan)
                        sub_b = jax.tree.map(jnp.asarray, sub_np)
                        lr = warmup_cosine(samples, peak_lr=job.peak_lr,
                                           min_lr=job.min_lr,
                                           warmup_steps=warmup_samples,
                                           total_steps=total_samples)
                        if engine is None:
                            step_fn = get_step(sub_plan, sub_b)
                        params, opt_state, metrics = step_fn(
                            params, opt_state, sub_b, lr)
                        samples += sub_plan.global_batch
                        sub_losses.append(float(metrics["loss"]))
                    loss = float(np.mean(sub_losses))
                    # the last sub-step's var_l1 sits on the sub-batch scale
                    # (E[var_l1] ≈ trΣ·J/b): rescale to the scheduled plan's
                    # batch so the controller sees the accumulated-path scale
                    var_l1 = (float(metrics["var_l1"])
                              * sub_plan.global_batch / plan.global_batch)
                    gsq = float(metrics["grad_sqnorm"])
                    exec_plan, opt_steps = sub_plan, repeats
                else:
                    if engine is not None:
                        batch_np = pad_to_bucket(batch_np, plan, bucket)
                        step_fn = engine.get_step(batch_np)
                        engine.observe(plan, bucket)
                    batch = jax.tree.map(jnp.asarray, batch_np)
                    lr = warmup_cosine(samples, peak_lr=job.peak_lr,
                                       min_lr=job.min_lr,
                                       warmup_steps=warmup_samples,
                                       total_steps=total_samples)
                    if engine is None:
                        step_fn = get_step(plan, batch)
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch, lr)
                    var_l1 = float(metrics["var_l1"])
                    gsq = float(metrics["grad_sqnorm"])
                    loss = float(metrics["loss"])
                    samples += plan.global_batch
                    exec_plan, opt_steps = plan, 1
                step += 1
                if job.schedule == "adaptive":
                    ctrl = controller_update(ctrl_cfg, ctrl, var_l1, gsq)
                if engine is not None:
                    # warmup AFTER the controller decision (DESIGN §14): warm
                    # the rung the fleet is actually headed to — the
                    # decided-growth rung when the controller just grew past
                    # this bucket, else the predicted target rung, else the
                    # next rung up.  The proposal is a pure function of
                    # globally-reduced stats, so every host proposes the same
                    # rung and PR 5's leader-decided agreement stays aligned.
                    proposal = None
                    if job.schedule == "adaptive":
                        if ctrl.plan.global_batch > bucket.global_batch:
                            proposal = engine.bucket_for(
                                ctrl.plan.global_batch)
                        elif job.predict and ctrl.pred_rung > bucket.global_batch:
                            proposal = engine.bucket_for(ctrl.pred_rung)
                    engine.warmup_agreed(bucket, batch_np, proposal=proposal)

                val = math.nan
                if job.eval_every and (step % job.eval_every == 0
                                       or step == job.steps):
                    val = eval_loss(params, step)

                t_stat = var_l1 / (job.eta**2 * gsq + 1e-30)
                history["step"].append(step)
                history["loss"].append(loss)
                history["val_loss"].append(val)
                history["global_batch"].append(plan.global_batch)
                history["T"].append(t_stat)
                history["var_l1"].append(var_l1)
                history["grad_sqnorm"].append(gsq)
                history["samples"].append(samples)
                history["time"].append(time.time() - t0)
                history["accum_steps"].append(exec_plan.accum_steps)
                history["opt_steps"].append(opt_steps)
                history["pred_rung"].append(
                    ctrl.pred_rung if job.schedule == "adaptive" else 0)
                history["pred_eta"].append(
                    ctrl.pred_eta_steps if job.schedule == "adaptive" else -1.0)
                if log_f:
                    log_f.write(
                        f"{step},{samples},{plan.global_batch},"
                        f"{exec_plan.accum_steps},{exec_plan.micro_batch},"
                        f"{loss:.4f},"
                        f"{val:.4f},{t_stat:.1f},{var_l1:.4g},{gsq:.4g},"
                        f"{time.time()-t0:.1f}\n")
                    log_f.flush()
                # save AFTER the step's metrics land (log line k precedes
                # checkpoint k: a resumed log never skips a line)
                if job.checkpoint_every and step % job.checkpoint_every == 0:
                    save_state()
    except CoordinationError as e:
        # a peer rank is dead or never arrived: the fleet cannot make
        # progress, but THIS rank's state is intact — checkpoint it and
        # exit cleanly (DESIGN §12) so a restarted fleet resumes from here
        # instead of from the last periodic save (or from scratch)
        save_state()
        history["coordination_failure"] = str(e)
        if log_f:
            log_f.close()
        if engine is not None:
            engine.drain(raise_errors=False)
        if coordinator is not None:
            coordinator.close()
        raise

    save_state()
    if log_f:
        log_f.close()
    if engine is not None:
        # failures were already recovered by get_step's sync fallback; they
        # surface as stats.warmup_failures rather than aborting the run
        engine.drain(raise_errors=False)
        history["engine"] = engine.stats.as_dict()
    if coordinator is not None:
        coordinator.close()
    # callers (benchmarks, examples) consume the pytree view
    history["final_params"] = (layout.unflatten(list(params))
                               if job.params_impl == "flat" else params)
    return history


def summarize(history: dict) -> dict:
    losses = [l for l in history["loss"] if math.isfinite(l)]
    vals = [v for v in history["val_loss"] if math.isfinite(v)]
    out = {
        "steps": history["step"][-1] if history["step"] else 0,
        "avg_batch": float(np.mean(history["global_batch"])) if history["global_batch"] else 0,
        "best_loss": min(losses) if losses else math.nan,
        "best_val_loss": min(vals) if vals else math.nan,
        "wall_s": history["time"][-1] if history["time"] else 0.0,
    }
    eng = history.get("engine")
    if eng:
        out["engine"] = {k: eng[k] for k in
                         ("compiles", "hit_rate", "padding_waste", "warmups",
                          "barrier_wait_s", "desyncs", "disk_cache_hits",
                          "transitions", "transition_hits")}
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainJob):
        name = "--" + f.name.replace("_", "-")
        if f.type == "bool" or isinstance(f.default, bool):
            p.add_argument(name, action="store_true", default=f.default)
        elif f.name == "stages":
            p.add_argument(name, type=str, default=None,
                           help="e.g. '0.025:16,0.025:64,0.95:256'")
        else:
            typ = type(f.default) if f.default is not None else str
            if f.default is None:
                typ = int
            p.add_argument(name, type=typ, default=f.default)
    args = p.parse_args(argv)
    kw = vars(args)
    if isinstance(kw.get("stages"), str) and kw["stages"]:
        kw["stages"] = tuple((float(a), int(b)) for a, b in
                             (s.split(":") for s in kw["stages"].split(",")))
    elif kw.get("stages") is None:
        kw["stages"] = TrainJob.stages
    job = TrainJob(**kw)
    hist = run_training(job)
    print(json.dumps(summarize(hist), indent=2))


if __name__ == "__main__":
    main()
