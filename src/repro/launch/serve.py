"""Serving driver: batched prefill + decode loop (smoke-scale on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --batch 4 --prompt-len 32 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.compat import set_mesh
from repro.launch.mesh import make_host_mesh
from repro.distributed.serve_step import make_decode_step, make_prefill
from repro.models import build_model


def run_serving(arch: str, *, smoke=True, batch=4, prompt_len=32, gen_len=32,
                mesh_data=1, mesh_model=1, seed=0, greedy=True):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    mesh = make_host_mesh(data=mesh_data, model=mesh_model)

    cache_len = prompt_len + gen_len
    rng = np.random.default_rng(seed)
    text_len = prompt_len - (cfg.frontend.num_prefix_tokens
                             if cfg.frontend.kind == "vision_stub" else 0)
    if text_len <= 0:
        # vision_stub edge: the frontend's prefix tokens consume the whole
        # prompt budget, leaving no text token to seed `prompts[:, 0]`
        raise ValueError(
            f"prompt_len={prompt_len} leaves no text tokens after the "
            f"vision frontend's {cfg.frontend.num_prefix_tokens} prefix "
            f"tokens (text_len={text_len}); pass prompt_len > "
            f"{cfg.frontend.num_prefix_tokens}")
    prompts = rng.integers(0, cfg.vocab_size, (batch, text_len)).astype(np.int32)

    dec_wrap, _ = make_decode_step(model, mesh, batch=batch)
    cache = model.init_cache(batch, cache_len)
    step_fn = dec_wrap(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache))

    with set_mesh(mesh):
        # "prefill" by streaming the prompt through decode (cache stays
        # shape-stable; production prefill uses model.prefill)
        t0 = time.time()
        tok = jnp.asarray(prompts[:, 0])
        for i in range(text_len):
            logits, cache = step_fn(params, cache, tok, jnp.int32(i))
            tok = jnp.asarray(prompts[:, i + 1]) if i + 1 < text_len else (
                jnp.argmax(logits, -1).astype(jnp.int32))
        # fence the async dispatch: without this the prefill work is still
        # in flight when the clock is read, and its compute leaks into the
        # decode timing below (tok depends on the final logits; cache is
        # blocked too so no prefill writes straddle the phase boundary)
        jax.block_until_ready((tok, cache))
        t_prefill = time.time() - t0

        # the first generated token came out of the (already-timed) prefill
        # phase above: the timed decode loop emits gen_len - 1 tokens
        generated = [tok]
        t0 = time.time()
        for i in range(text_len, text_len + gen_len - 1):
            logits, cache = step_fn(params, cache, tok, jnp.int32(i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            generated.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    # throughput over the tokens the decode timer actually saw: gen_len - 1
    # per sequence (dividing batch * gen_len by this loop overstated tok/s)
    decode_tokens = batch * (gen_len - 1)
    toks_per_s = decode_tokens / max(t_decode, 1e-9) if decode_tokens else 0.0
    return {"tokens": out, "prefill_s": t_prefill, "decode_s": t_decode,
            "decode_tokens_timed": decode_tokens,
            "decode_tok_per_s": toks_per_s}


def run_continuous_serving(arch: str, *, smoke=True, max_slots=8,
                           prompt_len=4, gen_len=8, load_steps=60,
                           arrival_rate=0.5, burst_every=20, burst_size=5,
                           mesh_data=1, mesh_model=1, seed=0,
                           latency_slo_s=0.0, aot_warmup=True, max_queue=0):
    """Bursty open-loop load against the continuous-batching serve tier.

    An open-loop arrival process (Poisson at `arrival_rate` requests per
    engine step, plus a deterministic burst of `burst_size` every
    `burst_every` steps) drives `ServeEngine` for `load_steps` steps; the
    driver then drains the backlog.  Arrivals do NOT wait for completions,
    so queue pressure — and the controller's rung — genuinely moves.

    After the load phase, a steady-state probe: with every rung warm, a
    fresh burst forces a request-batch-size change, which must be served
    from the warmed rung — a transition cache hit with ZERO new compiles.

    Returns a metrics dict (sustained req/s, p50/p99 request latency,
    decode tok/s, engine counters, rung trace, probe verdict).
    """
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    mesh = make_host_mesh(data=mesh_data, model=mesh_model)
    from repro.core.serve_controller import ServeControllerConfig, serve_ladder
    from repro.distributed.serve_engine import QueueFullError, ServeEngine

    cache_len = prompt_len + gen_len
    engine = ServeEngine(
        model, params, mesh, max_slots=max_slots, cache_len=cache_len,
        controller=ServeControllerConfig(ladder=serve_ladder(max_slots),
                                         latency_slo_s=latency_slo_s),
        aot_warmup=aot_warmup, max_queue=max_queue)
    rng = np.random.default_rng(seed)

    def submit_one():
        prompt = rng.integers(0, cfg.vocab_size, (prompt_len,)).astype(np.int32)
        try:
            engine.submit(prompt, max_new_tokens=gen_len)
        except QueueFullError:
            pass    # open-loop load-shed: counted in stats.requests_rejected

    completed = []
    rung_trace = []
    t_start = time.time()
    for i in range(load_steps):
        n = rng.poisson(arrival_rate)
        if burst_every and i % burst_every == 0:
            n += burst_size
        for _ in range(n):
            submit_one()
        report = engine.step()
        if report is not None:
            completed.extend(report["completed"])
            rung_trace.append(report["rung"])
    completed.extend(engine.run_until_drained())
    wall_s = max(time.time() - t_start, 1e-9)

    # ---- steady-state probe: rung change must hit a warmed executable ----
    engine.warm(engine.ladder)
    engine.drain(raise_errors=False)        # all background compiles landed
    compiles0 = engine.stats.compiles
    trans0 = engine.stats.rung_transitions
    hits0 = engine.stats.transition_hits
    probe_burst = min(max_slots, engine.current_rung * 2)
    if engine.current_rung >= max_slots:    # already at top: force a shrink
        probe_burst = 1
    for _ in range(probe_burst):
        submit_one()
    completed.extend(engine.run_until_drained())
    probe = {
        "rung_transitions": engine.stats.rung_transitions - trans0,
        "transition_hits": engine.stats.transition_hits - hits0,
        "new_compiles": engine.stats.compiles - compiles0,
    }
    probe["steady_state_transition_hit"] = bool(
        probe["rung_transitions"] >= 1
        and probe["transition_hits"] == probe["rung_transitions"]
        and probe["new_compiles"] == 0)

    lat = sorted(r.latency_s for r in completed)

    def pct(p):
        return lat[min(len(lat) - 1, int(p / 100 * len(lat)))] if lat else 0.0

    stats = engine.stats
    return {
        "requests_completed": len(lat),
        "sustained_req_per_s": len(lat) / wall_s,
        "p50_latency_s": pct(50),
        "p99_latency_s": pct(99),
        "decode_tok_per_s": stats.tokens_generated / wall_s,
        "wall_s": wall_s,
        "rung_trace": rung_trace,
        "probe": probe,
        "engine": stats.as_dict(),
    }


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--full", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen-len", type=int, default=32)
    p.add_argument("--continuous", action="store_true",
                   help="bursty open-loop load on the continuous-batching "
                        "tier instead of the fixed-batch driver")
    p.add_argument("--max-slots", type=int, default=8)
    p.add_argument("--load-steps", type=int, default=60)
    p.add_argument("--arrival-rate", type=float, default=0.5)
    p.add_argument("--burst-every", type=int, default=20)
    p.add_argument("--burst-size", type=int, default=5)
    p.add_argument("--max-queue", type=int, default=0,
                   help="reject submits once this many requests wait "
                        "(0 = unbounded)")
    args = p.parse_args(argv)
    if args.continuous:
        res = run_continuous_serving(
            args.arch, smoke=not args.full, max_slots=args.max_slots,
            prompt_len=args.prompt_len, gen_len=args.gen_len,
            load_steps=args.load_steps, arrival_rate=args.arrival_rate,
            burst_every=args.burst_every, burst_size=args.burst_size,
            max_queue=args.max_queue)
        print(f"served {res['requests_completed']} requests: "
              f"{res['sustained_req_per_s']:.2f} req/s, "
              f"p50 {res['p50_latency_s']:.3f}s p99 {res['p99_latency_s']:.3f}s, "
              f"{res['decode_tok_per_s']:.1f} tok/s")
        print("engine:", res["engine"])
        print("steady-state probe:", res["probe"])
        return
    res = run_serving(args.arch, smoke=not args.full, batch=args.batch,
                      prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"prefill {res['prefill_s']:.2f}s decode {res['decode_s']:.2f}s "
          f"({res['decode_tok_per_s']:.1f} tok/s)")
    print("sample:", res["tokens"][0][:16])


if __name__ == "__main__":
    main()
