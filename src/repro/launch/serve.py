"""Serving driver: batched prefill + decode loop (smoke-scale on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --batch 4 --prompt-len 32 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.compat import set_mesh
from repro.launch.mesh import make_host_mesh
from repro.distributed.serve_step import make_decode_step, make_prefill
from repro.models import build_model


def run_serving(arch: str, *, smoke=True, batch=4, prompt_len=32, gen_len=32,
                mesh_data=1, mesh_model=1, seed=0, greedy=True):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    mesh = make_host_mesh(data=mesh_data, model=mesh_model)

    cache_len = prompt_len + gen_len
    rng = np.random.default_rng(seed)
    text_len = prompt_len - (cfg.frontend.num_prefix_tokens
                             if cfg.frontend.kind == "vision_stub" else 0)
    prompts = rng.integers(0, cfg.vocab_size, (batch, text_len)).astype(np.int32)

    dec_wrap, _ = make_decode_step(model, mesh, batch=batch)
    cache = model.init_cache(batch, cache_len)
    step_fn = dec_wrap(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache))

    with set_mesh(mesh):
        # "prefill" by streaming the prompt through decode (cache stays
        # shape-stable; production prefill uses model.prefill)
        t0 = time.time()
        tok = jnp.asarray(prompts[:, 0])
        for i in range(text_len):
            logits, cache = step_fn(params, cache, tok, jnp.int32(i))
            tok = jnp.asarray(prompts[:, i + 1]) if i + 1 < text_len else (
                jnp.argmax(logits, -1).astype(jnp.int32))
        t_prefill = time.time() - t0

        generated = [tok]
        t0 = time.time()
        for i in range(text_len, text_len + gen_len - 1):
            logits, cache = step_fn(params, cache, tok, jnp.int32(i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            generated.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    toks_per_s = batch * gen_len / max(t_decode, 1e-9)
    return {"tokens": out, "prefill_s": t_prefill, "decode_s": t_decode,
            "decode_tok_per_s": toks_per_s}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--full", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen-len", type=int, default=32)
    args = p.parse_args(argv)
    res = run_serving(args.arch, smoke=not args.full, batch=args.batch,
                      prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"prefill {res['prefill_s']:.2f}s decode {res['decode_s']:.2f}s "
          f"({res['decode_tok_per_s']:.1f} tok/s)")
    print("sample:", res["tokens"][0][:16])


if __name__ == "__main__":
    main()
