import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape) combination
on the production meshes and extract memory / cost / collective evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count on first initialization (this is the only entry point that forces 512
host devices — tests and benchmarks see the real device count).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.configs.shapes import INPUT_SHAPES, input_specs
from repro.compat import set_mesh
from repro.launch.mesh import make_production_mesh, num_workers
from repro.launch.roofline import (
    roofline_terms, parse_collectives, model_flops_per_step)
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.distributed.train_step import make_fsdp_norm_step
from repro.distributed.serve_step import make_decode_step, make_prefill


def dryrun_config(arch: str, remat: str = "full"):
    """Full config tuned for lowering: bf16, remat, chunked xent."""
    cfg = get_config(arch)
    return cfg.replace(dtype="bfloat16", param_dtype="bfloat16",
                       remat=remat, xent_chunk=512)


def _compile_one(cfg, shape, mesh, step_impl: str, accum: int = 1,
                 variance_impl: str = "scalar", seqpar: bool = False):
    """Build + lower + compile the step for one config; returns compiled."""
    with set_mesh(mesh):
        return _compile_one_inner(cfg, shape, mesh, step_impl, accum,
                                  variance_impl, seqpar)


def _compile_one_inner(cfg, shape, mesh, step_impl: str, accum: int = 1,
                       variance_impl: str = "scalar", seqpar: bool = False):
    model = build_model(cfg)
    params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if shape.kind == "train":
        specs = input_specs(cfg, shape.name, accum=accum)
        opt_like = jax.eval_shape(init_adamw, params_like)
        if step_impl == "accum_norm":
            from repro.distributed.train_step import make_accum_norm_step
            wrap, _, _ = make_accum_norm_step(
                model, AdamWConfig(), mesh, params_like=params_like)
        else:
            wrap, _, _ = make_fsdp_norm_step(
                model, AdamWConfig(), mesh, params_like=params_like,
                variance_impl=variance_impl, sequence_parallel=seqpar)
        fn = wrap(specs)
        lowered = fn.lower(params_like, opt_like, specs,
                           jax.ShapeDtypeStruct((), jnp.float32))
    elif shape.kind == "prefill":
        specs = input_specs(cfg, shape.name)
        wrap, _ = make_prefill(model, mesh, batch=shape.global_batch,
                               params_like=params_like)
        fn = wrap(specs)
        lowered = fn.lower(params_like, specs)
    else:  # decode
        specs = input_specs(cfg, shape.name)
        wrap, _ = make_decode_step(model, mesh, batch=shape.global_batch,
                                   ring=specs["ring"], params_like=params_like)
        fn = wrap(specs["cache"])
        lowered = fn.lower(params_like, specs["cache"], specs["tokens"],
                           specs["pos"])
    return lowered.compile()


def _cost_and_collectives(compiled):
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jaxlib: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _depth_cfg(cfg, repeats: int):
    """Reduced-depth unrolled variant of cfg with `repeats` pattern repeats
    (full width/batch) — used to calibrate true per-layer cost, since XLA's
    cost analysis counts a while-loop body once regardless of trip count."""
    layers = len(cfg.prefix_pattern) + repeats * len(cfg.block_pattern)
    return cfg.replace(num_layers=layers, scan_layers=False)


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                step_impl: str = "fsdp_norm", calibrate: bool = True,
                accum: int = 1, remat: str = "full",
                variance_impl: str = "scalar", seqpar: bool = False,
                bucket_ladder: str = ""):
    """Lower + compile one combination; returns (compiled, record).

    Three compiles: (A) the full-depth scanned model — THE deliverable proof
    that the sharding lowers and fits, and the memory_analysis source;
    (B)+(C) depth-1 / depth-2 unrolled variants whose cost difference is the
    exact per-layer cost, extrapolated to full depth for §Roofline."""
    cfg = dryrun_config(arch, remat=remat)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    t0 = time.time()
    compiled = _compile_one(cfg, shape, mesh, step_impl, accum=accum,
                            variance_impl=variance_impl, seqpar=seqpar)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_rec[attr] = getattr(mem, attr, None)

    if calibrate:
        c1 = _compile_one(_depth_cfg(cfg, 1), shape, mesh, step_impl,
                          accum=accum, variance_impl=variance_impl,
                          seqpar=seqpar)
        f1, b1, coll1 = _cost_and_collectives(c1)
        del c1
        c2 = _compile_one(_depth_cfg(cfg, 2), shape, mesh, step_impl,
                          accum=accum, variance_impl=variance_impl,
                          seqpar=seqpar)
        f2, b2, coll2 = _cost_and_collectives(c2)
        del c2
        R = cfg.num_repeats
        flops = f1 + (R - 1) * (f2 - f1)
        hbm = b1 + (R - 1) * (b2 - b1)
        coll = {}
        for op in coll1:
            coll[op] = {
                k: coll1[op][k] + (R - 1) * (coll2[op][k] - coll1[op][k])
                for k in coll1[op]
            }
        cost = {"flops": flops, "bytes accessed": hbm,
                "calibration": {"f1": f1, "f2": f2, "repeats": R}}
        hlo_for_terms = ""   # collectives already extrapolated
        mflops = model_flops_per_step(cfg, shape, n_dev)
        rl = roofline_terms(cost, hlo_for_terms, mflops)
        from repro.launch.roofline import wire_bytes, PEAK_FLOPS, HBM_BW, ICI_BW
        wb = wire_bytes(coll)
        rl.wire_bytes = wb
        rl.collective_s = wb / ICI_BW
        terms = {"compute": rl.compute_s, "memory": rl.memory_s,
                 "collective": rl.collective_s}
        rl.bottleneck = max(terms, key=terms.get)
    else:
        fl, hb, coll = _cost_and_collectives(compiled)
        cost = {"flops": fl, "bytes accessed": hb}
        mflops = model_flops_per_step(cfg, shape, n_dev)
        rl = roofline_terms(cost, compiled.as_text(), mflops)

    ladder_rec = {}
    if bucket_ladder and shape.kind == "train":
        # ahead-of-time compile every accumulation rung of the bucket ladder
        # (the engine's warmup cost if the whole ladder is prebuilt)
        for m in (int(v) for v in bucket_ladder.split(",")):
            if m == accum or shape.global_batch % m != 0:
                continue
            t0m = time.time()
            cm = _compile_one(cfg, shape, mesh, step_impl, accum=m,
                              variance_impl=variance_impl, seqpar=seqpar)
            ladder_rec[f"M{m}"] = round(time.time() - t0m, 1)
            del cm

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step_impl": step_impl if shape.kind == "train" else shape.kind,
        "devices": n_dev,
        "workers_J": num_workers(mesh),
        "compile_s": round(t_compile, 1),
        "memory": mem_rec,
        "cost": cost,
        "collectives": coll,
        "roofline": rl.as_dict(),
        "params_total": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    if ladder_rec:
        record["bucket_ladder_compile_s"] = ladder_rec
    return compiled, record


def applicable(arch: str, shape_name: str) -> bool:
    """All 40 pairs lower: long_500k uses the native sub-quadratic path for
    SSM/hybrid archs and the sliding-window serving mode for the rest
    (DESIGN §4)."""
    return True


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--assigned-only", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--step-impl", default="fsdp_norm")
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--remat", default="full")
    p.add_argument("--variance-impl", default="scalar")
    p.add_argument("--bucket-ladder", default="",
                   help="comma list of accumulation rungs to AOT-compile, "
                        "e.g. '1,2,4,8' (train shapes only)")
    p.add_argument("--seqpar", action="store_true")
    p.add_argument("--tag", default="")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args(argv)

    archs = [args.arch] if args.arch else (
        list(ASSIGNED_ARCHS) if (args.all or args.assigned_only) else [])
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
                if args.step_impl != "fsdp_norm":
                    tag += f"__{args.step_impl}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    compiled, rec = lower_combo(
                        arch, shape_name, mp, step_impl=args.step_impl,
                        accum=args.accum, remat=args.remat,
                        variance_impl=args.variance_impl, seqpar=args.seqpar,
                        bucket_ladder=args.bucket_ladder)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=2, default=str)
                    rl = rec["roofline"]
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"flops/dev={rl['flops']:.3g} "
                          f"bottleneck={rl['bottleneck']}", flush=True)
                    del compiled
                except Exception as e:
                    failures.append((tag, repr(e)))
                    with open(path + ".fail", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"  FAIL: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\nall combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
