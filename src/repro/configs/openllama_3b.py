"""OpenLlama 3B — the paper's largest experiment model (Table 4/7).
d_model follows n_heads*d_head = 32*100 = 3200 (Table 4's 2048 is a typo)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="openllama-3b", arch_type="dense",
    num_layers=26, d_model=3200, num_heads=32, num_kv_heads=32,
    d_ff=8640, vocab_size=32000, head_dim=100,
    rope_theta=10000.0, mlp_kind="swiglu", tie_embeddings=False,
    source="paper Table 4; github.com/openlm-research/open_llama",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="openllama-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512)
