"""gemma2-27b [dense] — local+global alternating attention, logit softcaps,
post-norms, scaled embeddings [arXiv:2408.00118]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", arch_type="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
    d_ff=36864, vocab_size=256000, head_dim=128,
    block_pattern=("local", "attn"), sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_attn_norm=True, scale_embed=True,
    rope_theta=10000.0, mlp_kind="geglu", tie_embeddings=True,
    source="arXiv:2408.00118",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        sliding_window=16)
