"""whisper-base [audio] — encoder-decoder; mel+conv frontend is a STUB
(input_specs provides frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig, EncoderConfig, FrontendConfig

CONFIG = ModelConfig(
    name="whisper-base", arch_type="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    pos_embed="sinusoidal", mlp_kind="gelu", norm_kind="layernorm",
    encoder=EncoderConfig(num_layers=6, num_frames=1500),
    frontend=FrontendConfig(kind="audio_stub"),
    tie_embeddings=True, source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        encoder=EncoderConfig(num_layers=2, num_frames=16))
