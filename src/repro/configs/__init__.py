"""Architecture registry: 10 assigned archs + the paper's 3 Llama-2-family
experiment models.  `get_config(name)` / `get_smoke_config(name)`."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

# arch id -> module name
_REGISTRY = {
    # assigned architecture pool
    "dbrx-132b": "dbrx_132b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "whisper-base": "whisper_base",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-1b": "internvl2_1b",
    "gemma2-27b": "gemma2_27b",
    "nemotron-4-15b": "nemotron_4_15b",
    "mamba2-370m": "mamba2_370m",
    "llama3.2-1b": "llama3_2_1b",
    # the paper's own experiment models
    "microllama-300m": "microllama_300m",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "openllama-3b": "openllama_3b",
}

ASSIGNED_ARCHS = tuple(list(_REGISTRY)[:10])
PAPER_ARCHS = tuple(list(_REGISTRY)[10:])
ALL_ARCHS = tuple(_REGISTRY)


def _module(name: str):
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(f"repro.configs.{_REGISTRY[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()
