"""MicroLlama 300M — the paper's smallest experiment model (Table 4/5).

Paper Table 4 lists d_model=2048/n_heads=12/d_head=64, which is internally
inconsistent and yields ~550M params; the released MicroLlama-300M
(github.com/keeeeenw/MicroLlama) uses hidden_size=1024, intermediate=5632,
which reproduces the paper's stated 304.6M.  We follow the released model.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="microllama-300m", arch_type="dense",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=5632, vocab_size=32000, head_dim=64,
    rope_theta=10000.0, mlp_kind="swiglu", tie_embeddings=True,
    source="paper Table 4; github.com/keeeeenw/MicroLlama",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="microllama-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512)
