"""dbrx-132b [moe] — 16 experts top-4, fine-grained MoE
[hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", arch_type="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=4, d_expert=10752),
    rope_theta=500000.0, mlp_kind="swiglu", norm_kind="layernorm",
    tie_embeddings=False, source="hf:databricks/dbrx-base",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="dbrx-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128, capacity_factor=2.0))
