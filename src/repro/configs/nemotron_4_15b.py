"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", arch_type="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab_size=256000, head_dim=128,
    rope_theta=10000.0, mlp_kind="relu2", norm_kind="layernorm",
    tie_embeddings=False, source="arXiv:2402.16819",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="nemotron-4-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512)
