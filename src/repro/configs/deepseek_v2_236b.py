"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed experts
top-6, dense first layer [arXiv:2405.04434]."""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", arch_type="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab_size=102400, head_dim=128,
    block_pattern=("mla",), prefix_pattern=("mla",),  # layer 0 dense
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536,
                  num_shared_experts=2, shared_d_expert=1536, first_dense=1),
    rope_theta=10000.0, mlp_kind="swiglu", tie_embeddings=False,
    source="arXiv:2405.04434",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v2-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=128, vocab_size=512,
        prefix_pattern=("mla",),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      num_shared_experts=1, shared_d_expert=64, first_dense=1,
                      capacity_factor=2.0))
