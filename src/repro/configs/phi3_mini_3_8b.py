"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", arch_type="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    rope_theta=10000.0, mlp_kind="swiglu", tie_embeddings=False,
    source="arXiv:2404.14219",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="phi3-mini-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512)
