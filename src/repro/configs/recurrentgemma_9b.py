"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent : 1
attention [arXiv:2402.19427].

38 layers = 2 prefix recurrent blocks + 12 x (rglru, rglru, local); same 2:1
ratio and spacing as the released model (which starts the pattern at layer 0).
"""
from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", arch_type="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "local"), prefix_pattern=("rglru", "rglru"),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    sliding_window=2048, scale_embed=True,
    rope_theta=10000.0, mlp_kind="geglu", tie_embeddings=True,
    native_subquadratic=True, source="arXiv:2402.19427",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="recurrentgemma-smoke", num_layers=3, d_model=128, num_heads=4,
        num_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512,
        prefix_pattern=(), rglru=RGLRUConfig(lru_width=128, conv_width=4),
        sliding_window=16)
