"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

The FULL configs are exercised only through these specs (no allocation);
smoke tests instantiate reduced variants.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as tfm


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

_i32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _frontend_specs(cfg: ModelConfig, batch: int, seq: int):
    """Stub-frontend embeddings + adjusted text length (see DESIGN §4)."""
    extra = {}
    text_len = seq
    if cfg.frontend.kind == "vision_stub":
        np_ = cfg.frontend.num_prefix_tokens
        extra["patch_embeds"] = _sds((batch, np_, cfg.d_model), cfg.act_dtype)
        text_len = seq - np_
    elif cfg.frontend.kind == "audio_stub":
        extra["frames"] = _sds((batch, cfg.encoder.num_frames, cfg.d_model), cfg.act_dtype)
    return extra, text_len


def train_inputs(cfg: ModelConfig, shape: InputShape, accum: int = 1):
    """Stacked microbatches partitioning the global batch:
    (M, global_batch/M, seq) token/label specs."""
    assert shape.global_batch % accum == 0, (shape, accum)
    b, s = shape.global_batch // accum, shape.seq_len
    extra, text_len = _frontend_specs(cfg, b, s)
    batch = {
        "tokens": _sds((accum, b, text_len), _i32),
        "labels": _sds((accum, b, text_len), _i32),
    }
    for k, v in extra.items():
        batch[k] = _sds((accum,) + v.shape, v.dtype)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: InputShape):
    b, s = shape.global_batch, shape.seq_len
    extra, text_len = _frontend_specs(cfg, b, s)
    batch = {"tokens": _sds((b, text_len), _i32)}
    batch.update(extra)
    return batch


def decode_inputs(cfg: ModelConfig, shape: InputShape):
    """(tokens, pos, cache) specs for one decode step with a seq_len cache."""
    b, s = shape.global_batch, shape.seq_len
    ring = (shape.name == "long_500k") and not cfg.native_subquadratic
    cache = jax.eval_shape(
        functools.partial(tfm.init_decode_cache, cfg, b, s, ring=ring))
    return {
        "tokens": _sds((b,), _i32),
        "pos": _sds((), _i32),
        "cache": cache,
        "ring": ring,
    }


def input_specs(cfg: ModelConfig, shape_name: str, accum: int = 1):
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return train_inputs(cfg, shape, accum)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, shape)
    return decode_inputs(cfg, shape)
