"""llama3.2-1b [dense] — small Llama 3 [hf:meta-llama/Llama-3.2-1B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", arch_type="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=64,
    rope_theta=500000.0, mlp_kind="swiglu", tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="llama3.2-1b-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512)
