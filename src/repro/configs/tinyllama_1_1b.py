"""TinyLlama 1.1B — the paper's FSDP-Norm experiment model (Table 4/6)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", arch_type="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=5632, vocab_size=32000, head_dim=64,
    rope_theta=10000.0, mlp_kind="swiglu", tie_embeddings=False,
    source="paper Table 4; arXiv:2401.02385",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="tinyllama-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512)
