"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m", arch_type="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=1,
    block_pattern=("ssd",), mlp_kind="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=128),
    tie_embeddings=True, native_subquadratic=True,
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="mamba2-smoke", num_layers=2, d_model=128, vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_width=4,
                      chunk_size=8))
