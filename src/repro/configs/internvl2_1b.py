"""internvl2-1b [vlm] — InternViT frontend STUB (patch embeddings via
input_specs) + InternLM2-style LM backbone [arXiv:2404.16821]."""
from repro.models.config import ModelConfig, FrontendConfig

CONFIG = ModelConfig(
    name="internvl2-1b", arch_type="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    frontend=FrontendConfig(kind="vision_stub", num_prefix_tokens=256),
    rope_theta=1000000.0, mlp_kind="swiglu", tie_embeddings=True,
    source="arXiv:2404.16821",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="internvl2-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        frontend=FrontendConfig(kind="vision_stub", num_prefix_tokens=16))
