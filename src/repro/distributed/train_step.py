"""Distributed train steps.

Two builders (DESIGN §2/§7):

* `make_fsdp_norm_step` — the paper's DDP-/FSDP-Norm in its JAX-native form:
  `shard_map` manual over the data axes (each manual instance is one of the
  paper's J workers), GSPMD auto over the `model` axis (parameter sharding =
  the FSDP/TP part).  The per-worker minibatch gradient g_j exists explicitly
  before the `pmean`, exactly like the pre-all-reduce gradient in PyTorch
  DDP/FSDP, and the eq.(5) statistic is computed from it.

* `make_accum_norm_step` — beyond-paper ACCUM-NORM under pure GSPMD with
  full-mesh FSDP parameter sharding: the variance statistic comes from the M
  gradient-accumulation microbatch gradients, so no manual axes are needed
  and parameters/moments shard over all 256/512 chips.

Both take a stacked-microbatch batch {tokens/labels: (M, B_global, seq)} and
perform: accumulate grads over M -> statistic -> AdamW -> metrics.

Two residency switches (both default 'tree'):

* `stats_impl={tree,flat}` — how the statistics+AdamW tail runs: leaf-by-leaf
  pytree walk, or the DESIGN §9 bucketed flat buffers with fused single-pass
  kernels.
* `params_impl={tree,flat}` — the residency format of the PARAMETERS
  (DESIGN §10): 'flat' makes the bucketed buffers the live format — the
  step unflattens them once, accumulates leaf cotangents with the tree
  path's exact arithmetic, and transposes the result through the explicit
  pad-slice adjoint (`layout.pack_cotangents`, the linear transpose of
  `unflatten`) so gradients are *born flat* and the steady-state step
  graph carries ZERO pack eqns (asserted by the DESIGN §13 jaxpr counter,
  `repro.analysis.count_layout_ops`, with stats_impl='flat'; the tree
  oracle stays available for the differential equivalence suite).  `unflatten_for_grad` is the custom-vjp form of the
  same adjoint, used where a single `jax.grad` spans the whole update
  (local-SGD) and by the adjoint microbenchmarks/property tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.norm_test import (
    worker_variance_stats, worker_variance_stats_buffers,
    worker_variance_stats_flat, paper_faithful_worker_variance,
    accum_variance_stats, tree_sqnorm)
from repro.optim.adamw import (
    AdamWConfig, init_adamw, init_adamw_flat, adamw_update,
    adamw_update_buffers, clip_scale_from_norm)
from repro.distributed.flatbuf import FlatLayout
from repro.distributed.params import param_pspecs, opt_pspecs
from repro.distributed.sharding import (
    DEFAULT_RULES, MULTIPOD_RULES, manual_data_rules, use_sharding_rules,
    with_sequence_parallel, flat_buffer_specs, gather_flat_buffers,
    shard_flat_buffers)
from repro.compat import PARTIAL_AUTO_OK, shard_map
from repro.launch.mesh import data_axes, num_workers


def _manual_axes(mesh, daxes):
    """Manual axes for the hybrid steps: just the data axes when partial-auto
    shard_map works, the whole mesh on old JAX (see compat.PARTIAL_AUTO_OK)."""
    return tuple(daxes) if PARTIAL_AUTO_OK else tuple(mesh.axis_names)


def _tree_zeros_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _rules_for(mesh):
    return MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES


def _batch_pspec(batch_tree, daxes):
    """(M, B, ...) leaves: shard the global-batch dim over the data axes."""
    return jax.tree.map(lambda x: P(None, daxes) if x.ndim >= 2 else P(), batch_tree)


def _check_stats_impl(stats_impl: str, variance_impl: str = "scalar"):
    if stats_impl not in ("tree", "flat"):
        raise ValueError(f"stats_impl must be 'tree' or 'flat', got {stats_impl!r}")
    if stats_impl == "flat" and variance_impl == "paper":
        raise ValueError("variance_impl='paper' (full-vector all-reduce "
                         "baseline) has no flat-buffer path; use stats_impl='tree'")


def _check_params_impl(params_impl: str, variance_impl: str = "scalar"):
    if params_impl not in ("tree", "flat"):
        raise ValueError(
            f"params_impl must be 'tree' or 'flat', got {params_impl!r}")
    if params_impl == "flat" and variance_impl == "paper":
        raise ValueError("variance_impl='paper' walks tree-resident gradient "
                         "leaves; use params_impl='tree'")


def _opt_like_for(stats_impl: str, params_like, shard_divisor: int = 1,
                  layout=None):
    """Abstract optimizer state: pytree moments ('tree') or the DESIGN §9
    flat bucketed buffers ('flat', padded to `shard_divisor`-divisible
    buckets so they shard evenly over the data axes)."""
    if stats_impl == "flat":
        return jax.eval_shape(
            functools.partial(init_adamw_flat, shard_divisor=shard_divisor,
                              layout=layout),
            params_like)
    return jax.eval_shape(init_adamw, params_like)


def _worker_index(mesh, daxes):
    """This manual instance's flattened data-worker index j ∈ [0, J), first
    data axis major — the same order `P(daxes)` lays bucket shards out in."""
    idx = jnp.zeros((), jnp.int32)
    for a in daxes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _shard_bucket(b, idx, J):
    """Worker `idx`'s 1/J slice of one J-divisible bucket buffer (J is a
    trace-time constant: the J=1 slice is the identity, not a copy)."""
    if J == 1:
        return b
    n = b.shape[0] // J
    return jax.lax.dynamic_slice_in_dim(b, idx * n, n)


def _sharded_buffer_update(pb_local, gb, opt_state, opt_cfg, lr,
                           grad_sqnorm, mesh, daxes):
    """Core of the FSDP-style sharded flat AdamW inside the shard_map manual
    region (DESIGN §9/§10 sharded flat buckets).

    The moment buffers arrive as this worker's 1/J bucket shard (in_specs
    `P(daxes)`), and `pb_local` is the worker's 1/J slice of the packed
    parameter buffers; the mean-gradient buffers are replicated inside the
    manual region, so each worker slices out its own gradient shard and
    runs the fused update on 1/J of the data (per-worker moment memory AND
    update flops drop by J).  Bucket sizes are J-divisible by construction
    (`FlatLayout.from_tree(shard_divisor=J)`), so the slices are exact.
    `grad_sqnorm` is the globally-reduced Σ‖g‖² from the fused statistics —
    the clip scale needs the GLOBAL norm, which a per-shard kernel
    byproduct could not provide.

    Returns the worker's updated param SHARDS: the flat-resident step emits
    them directly (out_specs `P(daxes)`, the next step's `gather_flat_buffers`
    reassembles them); the tree-resident wrapper below all-gathers here."""
    J = num_workers(mesh)
    idx = _worker_index(mesh, daxes) if J > 1 else jnp.zeros((), jnp.int32)
    gb_local = [_shard_bucket(b, idx, J) for b in gb]
    new_pl, new_mb, new_vb, count, gnorm, _ = adamw_update_buffers(
        list(pb_local), gb_local, list(opt_state["m"]), list(opt_state["v"]),
        opt_cfg, lr, opt_state["count"], grad_sqnorm=grad_sqnorm)
    new_opt = {"m": tuple(new_mb), "v": tuple(new_vb), "count": count}
    return new_pl, new_opt, gnorm


def _flat_sharded_update(layout, params, gb, opt_state, opt_cfg, lr,
                         grad_sqnorm, mesh, daxes):
    """Tree-resident wrapper over `_sharded_buffer_update`: pack the params
    once against the shared layout, slice this worker's shard, update, and
    all-gather only the updated parameter shards back to the replicated
    pytree layout (DESIGN §9 dataflow for stats_impl='flat')."""
    J = num_workers(mesh)
    idx = _worker_index(mesh, daxes)
    pb_local = [_shard_bucket(b, idx, J) for b in layout.flatten(params)]
    new_pl, new_opt, gnorm = _sharded_buffer_update(
        pb_local, gb, opt_state, opt_cfg, lr, grad_sqnorm, mesh, daxes)
    new_pb = (new_pl if J == 1 else
              [jax.lax.all_gather(p, daxes, tiled=True) for p in new_pl])
    return layout.unflatten(new_pb), new_opt, gnorm


def _accumulate(loss_fn, params, batch, track_micro_sqnorm: bool):
    """lax.scan over the M stacked microbatches; returns (mean grads g,
    mean loss, mean aux, Σ_m ‖ĝ^m‖² if tracked, effective microbatch count).

    `loss_fn(params, microbatch) -> (loss, metrics)`; `params` is whatever
    the loss differentiates — the model pytree, or a tuple of flat-resident
    buffers (DESIGN §10), in which case the gradients accumulate as f32
    buffers: everything here is residency-agnostic tree arithmetic.

    Microbatch contributions are weighted by their VALID-TOKEN count
    (labels >= 0), normalized by the total.  With the full, equal-sized
    microbatches of an unpadded batch this is exactly the old uniform mean;
    under the bucketed engine's padding (DESIGN §8) it makes padded slots —
    whole microbatches of `labels = -1` slots or a padded tail inside one —
    contribute nothing, so padded and unpadded batches produce identical
    loss and gradients."""

    def body(carry, mb):
        acc_g, acc_loss, acc_aux, acc_sq, acc_w, acc_m = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        w = jnp.sum(mb["labels"] >= 0).astype(jnp.float32)
        acc_g = jax.tree.map(lambda a, b: a + w * b.astype(jnp.float32), acc_g, g)
        if track_micro_sqnorm:
            # fully-padded microbatches carry no gradient draw: skip them in
            # the Σ_m ‖ĝ^m‖² used by the accumulation-variance estimator
            acc_sq = acc_sq + jnp.where(w > 0, tree_sqnorm(g), 0.0)
        return (acc_g, acc_loss + w * loss, acc_aux + w * metrics["aux"],
                acc_sq, acc_w + w, acc_m + (w > 0)), None

    init = (_tree_zeros_f32(params), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    (acc_g, acc_loss, acc_aux, acc_sq, acc_w, acc_m), _ = \
        jax.lax.scan(body, init, batch)
    denom = jnp.maximum(acc_w, 1.0)
    g = jax.tree.map(lambda x: x / denom, acc_g)
    return g, acc_loss / denom, acc_aux / denom, acc_sq, acc_m, acc_w


def _accumulate_buffers(loss_fn, layout, pb, batch,
                        track_micro_sqnorm: bool):
    """Flat-resident gradient accumulation (DESIGN §10): unflatten the
    param buffers ONCE per step, let the microbatch scan accumulate
    per-micro leaf cotangents with the EXACT arithmetic of the tree path
    (`_accumulate` on the tree view — XLA fuses the leaf adds into the
    backward; per-micro Σ‖ĝ^m‖² comes for free when tracked), and
    transpose the accumulated cotangent through the explicit pad-slice
    adjoint (`layout.pack_cotangents`) exactly once: one gradient-size
    concat per step, not M.

    Two rejected alternatives, for the record: differentiating the loss
    through unflatten per MICROBATCH accumulates in buffer space — an
    extra gradient-size concat+add every scan iteration, measured ~15% of
    CPU step time at M=4; differentiating the whole scan in one
    `jax.grad` folds the 1/W normalization into each microbatch cotangent,
    drifting ~5e-5 from the tree oracle over 5 AdamW steps.  The adjoint
    is LINEAR, so transposing the accumulated cotangent here is bit-exact
    to accumulating per-micro transposed buffers — and applying it via
    `pack_cotangents` (not a dtype-strict `jax.vjp`) keeps the f32
    accumulators intact for low-precision params, matching the tree path
    and the flat-stats pack of f32 gradients exactly.

    Returns `_accumulate`'s tuple with g as born-flat f32 buffers."""
    tree = layout.unflatten(list(pb))
    g_tree, loss, aux, sq, m_eff, w = _accumulate(loss_fn, tree, batch,
                                                  track_micro_sqnorm)
    gb = layout.pack_cotangents(g_tree)
    return gb, loss, aux, sq, m_eff, w


# --------------------------------------------------------- FSDP-Norm ----

def make_fsdp_norm_step(model, opt_cfg: AdamWConfig, mesh, *,
                        variance_impl: str = "scalar",
                        stats_impl: str = "tree",
                        params_impl: str = "tree",
                        sequence_parallel: bool = False,
                        params_like=None, jit: bool = True):
    """variance_impl: 'scalar' (pre-reduced 8-byte collective, DESIGN §7.1)
    or 'paper' (eq. 5 literal: all-reduce the full (g_j-g)² vector).

    stats_impl: 'tree' (leaf-by-leaf reference path) or 'flat' (DESIGN §9:
    bucketed flat buffers, single-pass fused statistics, one AdamW launch
    per bucket; optimizer state from `init_adamw_flat(shard_divisor=J)` —
    the moment buffers are SHARDED over the data axes, and the mean
    gradient is packed exactly once per step).

    params_impl: 'tree' (params are the model pytree, replicated across the
    data axes) or 'flat' (DESIGN §10: params REST as their `P(daxes)` 1/J
    bucket shard; the step all-gathers the shards into full buffers, the
    accumulated gradient transposes through the explicit pad-slice adjoint
    so it is born flat, and only the worker's updated param shard leaves
    the step — with stats_impl='flat' the steady-state step performs ZERO
    packs).

    The shared per-step-signature `FlatLayout` is exposed as
    `wrap.flat_layout` (None on the pure tree path) so callers — the
    training loop, the bucketed engine, checkpointing — reuse ONE layout
    across every ladder rung instead of rebuilding per trace."""
    _check_stats_impl(stats_impl, variance_impl)
    _check_params_impl(params_impl, variance_impl)
    daxes = data_axes(mesh)
    J = num_workers(mesh)
    manual = _manual_axes(mesh, daxes)
    base = _rules_for(mesh)
    if sequence_parallel:
        base = with_sequence_parallel(base)
    rules = manual_data_rules(base, manual)

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # ONE layout per step signature, shared by the statistics and the AdamW
    # tail (packs happen against it exactly once per tree per step) and by
    # every bucket the engine compiles
    layout = (FlatLayout.from_tree(params_like, shard_divisor=J)
              if (stats_impl == "flat" or params_impl == "flat") else None)

    def inner(params, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            if params_impl == "flat":
                # params arrive as this worker's 1/J bucket shard; gather to
                # full buffers and differentiate the whole accumulation
                # straight through unflatten — g_j is born flat, one
                # adjoint pack for the whole step (J is static: no gather
                # ops on a 1-worker mesh)
                pb_full = (tuple(params) if J == 1 else
                           tuple(gather_flat_buffers(params, daxes)))
                g_j, loss, aux, _, _, w_j = _accumulate_buffers(
                    model.loss, layout, pb_full, batch, False)
            else:
                g_j, loss, aux, _, _, w_j = _accumulate(
                    model.loss, params, batch, False)
            # valid-token-weighted mean over workers: equals plain pmean on
            # unpadded batches; exact under the engine's padding even when
            # the padded tail lands unevenly across workers (DESIGN §8)
            w_sum = jnp.maximum(jax.lax.psum(w_j, daxes), 1.0)
            g = jax.tree.map(
                lambda x: jax.lax.psum(x * w_j, daxes) / w_sum, g_j)
            if params_impl == "flat":
                if stats_impl == "flat":
                    # born-flat single-pass pair: no pack anywhere
                    var_l1, gsq = worker_variance_stats_buffers(g_j, g, daxes)
                else:
                    # tree oracle over the unflattened gradient views
                    var_l1, gsq = worker_variance_stats(
                        layout.unflatten(list(g_j)), layout.unflatten(list(g)),
                        daxes)
            elif stats_impl == "flat":
                # single-pass fused pair; the packed mean-gradient buffers
                # come back and feed the update directly — g is packed ONCE
                var_l1, gsq, gb = worker_variance_stats_flat(
                    g_j, g, daxes, layout=layout)
            elif variance_impl == "paper":
                var_l1, gsq = paper_faithful_worker_variance(g_j, g, daxes)
            else:
                var_l1, gsq = worker_variance_stats(g_j, g, daxes)
            loss = jax.lax.psum(loss * w_j, daxes) / w_sum
            aux = jax.lax.psum(aux * w_j, daxes) / w_sum
            if params_impl == "flat":
                if stats_impl == "flat":
                    # the input params ARE the worker's param shard; the
                    # updated shards leave the step directly (the next
                    # step's gather reassembles them)
                    new_pl, new_opt, gnorm = _sharded_buffer_update(
                        list(params), list(g), opt_state, opt_cfg, lr, gsq,
                        mesh, daxes)
                    new_params = tuple(new_pl)
                else:
                    # tree-oracle tail on the unflattened views, then one
                    # pack + slice back to the resident shard
                    new_tree, new_opt, gnorm = adamw_update(
                        layout.unflatten(list(pb_full)),
                        layout.unflatten(list(g)), opt_state, opt_cfg, lr)
                    idx = _worker_index(mesh, daxes)
                    new_params = tuple(
                        _shard_bucket(b, idx, J)
                        for b in layout.flatten(new_tree))
            elif stats_impl == "flat":
                # per-bucket fused AdamW on this worker's 1/J bucket shard;
                # the ‖g‖² from the statistics doubles as the clip norm
                new_params, new_opt, gnorm = _flat_sharded_update(
                    layout, params, gb, opt_state, opt_cfg, lr, gsq,
                    mesh, daxes)
            else:
                new_params, new_opt, gnorm = adamw_update(
                    params, g, opt_state, opt_cfg, lr)
        metrics = {"loss": loss, "aux": aux, "var_l1": var_l1,
                   "grad_sqnorm": gsq, "grad_norm": gnorm,
                   "clip_scale": clip_scale_from_norm(gnorm, opt_cfg.grad_clip)}
        return new_params, new_opt, metrics

    p_tree_specs = param_pspecs(params_like, mesh, fsdp=False)
    # bucketed 1-D param buffers REST as their P(daxes) 1/J shard
    p_specs = (flat_buffer_specs(layout.num_buffers, daxes)
               if params_impl == "flat" else p_tree_specs)
    opt_like = _opt_like_for(stats_impl, params_like, shard_divisor=J,
                             layout=layout)
    if stats_impl == "flat":
        # bucketed 1-D buffers: moments sharded over the data axes (the
        # per-worker ~J× optimizer-memory saving), step count replicated
        bspecs = flat_buffer_specs(layout.num_buffers, daxes)
        o_specs = {"m": bspecs, "v": bspecs, "count": P()}
    else:
        o_specs = {"m": p_tree_specs, "v": p_tree_specs, "count": P()}

    def batch_specs(batch_like):
        return _batch_pspec(batch_like, daxes)

    # inside the manual region, sharded flat buffers (moments, and the param
    # buffers on the flat-resident path) enter/leave as the worker's local
    # shard; everything else stays replicated
    o_sm_specs = (o_specs if stats_impl == "flat"
                  else jax.tree.map(lambda _: P(), opt_like))
    p_sm_specs = (p_specs if params_impl == "flat"
                  else jax.tree.map(lambda _: P(), params_like))

    def wrap(batch_like):
        sm = shard_map(
            inner, mesh=mesh,
            in_specs=(p_sm_specs,
                      o_sm_specs,
                      batch_specs(batch_like), P()),
            out_specs=(p_sm_specs,
                       o_sm_specs,
                       {"loss": P(), "aux": P(), "var_l1": P(),
                        "grad_sqnorm": P(), "grad_norm": P(),
                        "clip_scale": P()}),
            axis_names=set(manual), check_vma=False)
        if not jit:
            return sm
        return jax.jit(
            sm,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s),
                             batch_specs(batch_like),
                             is_leaf=lambda s: isinstance(s, P)),
                None),
            out_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                None),
            donate_argnums=(0, 1))

    wrap.flat_layout = layout
    return wrap, p_specs, o_specs


# -------------------------------------------------------- ACCUM-NORM ----

def make_accum_norm_step(model, opt_cfg: AdamWConfig, mesh, *,
                         stats_impl: str = "tree",
                         params_impl: str = "tree",
                         params_like=None, jit: bool = True):
    """Beyond-paper: pure-GSPMD step with full-mesh FSDP params; variance from
    accumulation microbatches (requires M >= 2 for a signal).

    stats_impl='flat' (DESIGN §9): the AdamW tail runs over bucketed flat
    buffers and its Σ‖g‖² kernel byproduct feeds the variance statistic and
    the grad_norm metric — zero extra gradient-sized passes, and the mean
    gradient is packed exactly once per step.  Flat moment buffers carry
    data-axis `PartitionSpec`s (J-divisible buckets), so the flat path
    composes with full-mesh FSDP instead of replicating optimizer state.

    params_impl='flat' (DESIGN §10): the param buffers themselves are the
    residency format (jit in/out shardings `P(daxes)` per bucket, GSPMD
    partitions the tail); the accumulated gradient transposes through the
    explicit pad-slice adjoint, so it is born flat — with stats_impl='flat'
    the step performs ZERO packs.  The shared layout is exposed as
    `wrap.flat_layout`."""
    _check_stats_impl(stats_impl)
    _check_params_impl(params_impl)
    daxes = data_axes(mesh)
    rules = _rules_for(mesh)
    J = num_workers(mesh)

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    layout = (FlatLayout.from_tree(params_like, shard_divisor=J)
              if (stats_impl == "flat" or params_impl == "flat") else None)

    def step(params, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            # constrain the batch over data axes (GSPMD)
            batch = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, P(None, daxes)) if x.ndim >= 2 else x, batch)
            if params_impl == "flat":
                # no sharding constraint on the param buffers: they arrive
                # as committed jit inputs already carrying the P(daxes)
                # in_shardings (a redundant constraint costs a copy on
                # XLA-CPU 0.4.x)
                pb = tuple(params)
                g, loss, aux, sq_sum, m_eff, _ = _accumulate_buffers(
                    model.loss, layout, pb, batch, True)
                gb = shard_flat_buffers(list(g), daxes)
                if stats_impl == "flat":
                    # born-flat buffers straight into the fused tail: the
                    # Σg² byproduct feeds the variance statistic — no packs
                    new_pb, new_mb, new_vb, count, gnorm, gsq = \
                        adamw_update_buffers(
                            list(pb), gb, list(opt_state["m"]),
                            list(opt_state["v"]), opt_cfg, lr,
                            opt_state["count"])
                    new_params = tuple(new_pb)
                    new_opt = {"m": tuple(new_mb), "v": tuple(new_vb),
                               "count": count}
                    var_l1, gsq = accum_variance_stats(sq_sum, None, m_eff, J,
                                                       gsq=gsq)
                else:
                    # tree-oracle tail over the unflattened views, then one
                    # pack back to the resident buffers
                    g_tree = layout.unflatten(gb)
                    var_l1, gsq = accum_variance_stats(sq_sum, g_tree,
                                                       m_eff, J)
                    new_tree, new_opt, gnorm = adamw_update(
                        layout.unflatten(list(pb)), g_tree, opt_state,
                        opt_cfg, lr)
                    new_params = tuple(shard_flat_buffers(
                        layout.flatten(new_tree), daxes))
            else:
                g, loss, aux, sq_sum, m_eff, _ = _accumulate(
                    model.loss, params, batch, True)
                if stats_impl == "flat":
                    # pack g and params ONCE against the shared layout, keep
                    # the buffers on the data axes, and run the pack-free tail
                    gb = shard_flat_buffers(layout.flatten(g), daxes)
                    pb = shard_flat_buffers(layout.flatten(params), daxes)
                    new_pb, new_mb, new_vb, count, gnorm, gsq = \
                        adamw_update_buffers(
                            pb, gb, list(opt_state["m"]),
                            list(opt_state["v"]),
                            opt_cfg, lr, opt_state["count"])
                    new_params = layout.unflatten(new_pb)
                    new_opt = {"m": tuple(new_mb), "v": tuple(new_vb),
                               "count": count}
                    var_l1, gsq = accum_variance_stats(sq_sum, g, m_eff, J,
                                                       gsq=gsq)
                else:
                    var_l1, gsq = accum_variance_stats(sq_sum, g, m_eff, J)
                    new_params, new_opt, gnorm = adamw_update(
                        params, g, opt_state, opt_cfg, lr)
        metrics = {"loss": loss, "aux": aux, "var_l1": var_l1,
                   "grad_sqnorm": gsq, "grad_norm": gnorm,
                   "clip_scale": clip_scale_from_norm(gnorm, opt_cfg.grad_clip)}
        return new_params, new_opt, metrics

    if params_impl == "flat":
        p_specs = flat_buffer_specs(layout.num_buffers, daxes)
    else:
        p_specs = param_pspecs(params_like, mesh, fsdp=True)
    if stats_impl == "flat":
        bspecs = flat_buffer_specs(layout.num_buffers, daxes)
        o_specs = {"m": bspecs, "v": bspecs, "count": P()}
    else:
        tree_specs = param_pspecs(params_like, mesh, fsdp=True)
        o_specs = {"m": tree_specs, "v": tree_specs, "count": P()}

    def wrap(batch_like):
        if not jit:
            return step
        return jax.jit(
            step,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda x: NamedSharding(mesh, P(None, daxes))
                             if x.ndim >= 2 else NamedSharding(mesh, P()),
                             batch_like),
                None),
            # pin outputs to the declared layout: GSPMD propagation would
            # otherwise pick its own param/moment shardings, and feeding
            # step t's output into step t+1 would conflict with in_shardings
            out_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                None),
            donate_argnums=(0, 1))

    wrap.flat_layout = layout
    return wrap, p_specs, o_specs
