"""Distributed train steps.

Two builders (DESIGN §2/§7):

* `make_fsdp_norm_step` — the paper's DDP-/FSDP-Norm in its JAX-native form:
  `shard_map` manual over the data axes (each manual instance is one of the
  paper's J workers), GSPMD auto over the `model` axis (parameter sharding =
  the FSDP/TP part).  The per-worker minibatch gradient g_j exists explicitly
  before the `pmean`, exactly like the pre-all-reduce gradient in PyTorch
  DDP/FSDP, and the eq.(5) statistic is computed from it.

* `make_accum_norm_step` — beyond-paper ACCUM-NORM under pure GSPMD with
  full-mesh FSDP parameter sharding: the variance statistic comes from the M
  gradient-accumulation microbatch gradients, so no manual axes are needed
  and parameters/moments shard over all 256/512 chips.

Both take a stacked-microbatch batch {tokens/labels: (M, B_global, seq)} and
perform: accumulate grads over M -> statistic -> AdamW -> metrics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.norm_test import (
    worker_variance_stats, paper_faithful_worker_variance,
    accum_variance_stats, tree_sqnorm)
from repro.optim.adamw import AdamWConfig, init_adamw, adamw_update
from repro.distributed.params import param_pspecs, opt_pspecs
from repro.distributed.sharding import (
    DEFAULT_RULES, MULTIPOD_RULES, manual_data_rules, use_sharding_rules,
    with_sequence_parallel)
from repro.launch.mesh import data_axes, num_workers


def _tree_zeros_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _rules_for(mesh):
    return MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES


def _batch_pspec(batch_tree, daxes):
    """(M, B, ...) leaves: shard the global-batch dim over the data axes."""
    return jax.tree.map(lambda x: P(None, daxes) if x.ndim >= 2 else P(), batch_tree)


def _accumulate(model, params, batch, track_micro_sqnorm: bool):
    """lax.scan over the M stacked microbatches; returns (mean grads g,
    mean loss, mean aux, Σ_m ‖ĝ^m‖² if tracked)."""
    m_steps = jax.tree.leaves(batch)[0].shape[0]

    def loss_fn(p, mb):
        loss, metrics = model.loss(p, mb)
        return loss, metrics

    def body(carry, mb):
        acc_g, acc_loss, acc_aux, acc_sq = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc_g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc_g, g)
        sq = tree_sqnorm(g) if track_micro_sqnorm else acc_sq
        return (acc_g, acc_loss + loss, acc_aux + metrics["aux"],
                acc_sq + sq if track_micro_sqnorm else acc_sq), None

    init = (_tree_zeros_f32(params), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    (acc_g, acc_loss, acc_aux, acc_sq), _ = jax.lax.scan(body, init, batch)
    g = jax.tree.map(lambda x: x / m_steps, acc_g)
    return g, acc_loss / m_steps, acc_aux / m_steps, acc_sq, m_steps


# --------------------------------------------------------- FSDP-Norm ----

def make_fsdp_norm_step(model, opt_cfg: AdamWConfig, mesh, *,
                        variance_impl: str = "scalar",
                        sequence_parallel: bool = False,
                        params_like=None, jit: bool = True):
    """variance_impl: 'scalar' (pre-reduced 8-byte collective, DESIGN §7.1)
    or 'paper' (eq. 5 literal: all-reduce the full (g_j-g)² vector)."""
    daxes = data_axes(mesh)
    base = _rules_for(mesh)
    if sequence_parallel:
        base = with_sequence_parallel(base)
    rules = manual_data_rules(base, daxes)

    def inner(params, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            g_j, loss, aux, _, m_steps = _accumulate(model, params, batch, False)
            g = jax.tree.map(lambda x: jax.lax.pmean(x, daxes), g_j)
            if variance_impl == "paper":
                var_l1, gsq = paper_faithful_worker_variance(g_j, g, daxes)
            else:
                var_l1, gsq = worker_variance_stats(g_j, g, daxes)
            loss = jax.lax.pmean(loss, daxes)
            aux = jax.lax.pmean(aux, daxes)
            new_params, new_opt, gnorm = adamw_update(params, g, opt_state, opt_cfg, lr)
        metrics = {"loss": loss, "aux": aux, "var_l1": var_l1,
                   "grad_sqnorm": gsq, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=False)
    opt_like = jax.eval_shape(init_adamw, params_like)
    o_specs = {"m": p_specs, "v": p_specs, "count": P()}

    def batch_specs(batch_like):
        return _batch_pspec(batch_like, daxes)

    def wrap(batch_like):
        sm = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params_like),
                      jax.tree.map(lambda _: P(), opt_like),
                      batch_specs(batch_like), P()),
            out_specs=(jax.tree.map(lambda _: P(), params_like),
                       jax.tree.map(lambda _: P(), opt_like),
                       {"loss": P(), "aux": P(), "var_l1": P(),
                        "grad_sqnorm": P(), "grad_norm": P()}),
            axis_names=set(daxes), check_vma=False)
        if not jit:
            return sm
        return jax.jit(
            sm,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s),
                             batch_specs(batch_like),
                             is_leaf=lambda s: isinstance(s, P)),
                None),
            out_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                None),
            donate_argnums=(0, 1))

    return wrap, p_specs, o_specs


# -------------------------------------------------------- ACCUM-NORM ----

def make_accum_norm_step(model, opt_cfg: AdamWConfig, mesh, *,
                         params_like=None, jit: bool = True):
    """Beyond-paper: pure-GSPMD step with full-mesh FSDP params; variance from
    accumulation microbatches (requires M >= 2 for a signal)."""
    daxes = data_axes(mesh)
    rules = _rules_for(mesh)
    J = num_workers(mesh)

    def step(params, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            # constrain the batch over data axes (GSPMD)
            batch = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, P(None, daxes)) if x.ndim >= 2 else x, batch)
            g, loss, aux, sq_sum, m_steps = _accumulate(model, params, batch, True)
            var_l1, gsq = accum_variance_stats(sq_sum, g, m_steps, J)
            new_params, new_opt, gnorm = adamw_update(params, g, opt_state, opt_cfg, lr)
        metrics = {"loss": loss, "aux": aux, "var_l1": var_l1,
                   "grad_sqnorm": gsq, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=True)
    o_specs = {"m": p_specs, "v": p_specs, "count": P()}

    def wrap(batch_like):
        if not jit:
            return step
        return jax.jit(
            step,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda x: NamedSharding(mesh, P(None, daxes))
                             if x.ndim >= 2 else NamedSharding(mesh, P()),
                             batch_like),
                None),
            donate_argnums=(0, 1))

    return wrap, p_specs, o_specs
