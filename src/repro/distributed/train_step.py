"""Distributed train steps.

Two builders (DESIGN §2/§7):

* `make_fsdp_norm_step` — the paper's DDP-/FSDP-Norm in its JAX-native form:
  `shard_map` manual over the data axes (each manual instance is one of the
  paper's J workers), GSPMD auto over the `model` axis (parameter sharding =
  the FSDP/TP part).  The per-worker minibatch gradient g_j exists explicitly
  before the `pmean`, exactly like the pre-all-reduce gradient in PyTorch
  DDP/FSDP, and the eq.(5) statistic is computed from it.

* `make_accum_norm_step` — beyond-paper ACCUM-NORM under pure GSPMD with
  full-mesh FSDP parameter sharding: the variance statistic comes from the M
  gradient-accumulation microbatch gradients, so no manual axes are needed
  and parameters/moments shard over all 256/512 chips.

Both take a stacked-microbatch batch {tokens/labels: (M, B_global, seq)} and
perform: accumulate grads over M -> statistic -> AdamW -> metrics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.norm_test import (
    worker_variance_stats, worker_variance_stats_flat,
    paper_faithful_worker_variance, accum_variance_stats, tree_sqnorm)
from repro.optim.adamw import (
    AdamWConfig, init_adamw, init_adamw_flat, adamw_update, adamw_update_flat)
from repro.distributed.params import param_pspecs, opt_pspecs
from repro.distributed.sharding import (
    DEFAULT_RULES, MULTIPOD_RULES, manual_data_rules, use_sharding_rules,
    with_sequence_parallel)
from repro.compat import PARTIAL_AUTO_OK, shard_map
from repro.launch.mesh import data_axes, num_workers


def _manual_axes(mesh, daxes):
    """Manual axes for the hybrid steps: just the data axes when partial-auto
    shard_map works, the whole mesh on old JAX (see compat.PARTIAL_AUTO_OK)."""
    return tuple(daxes) if PARTIAL_AUTO_OK else tuple(mesh.axis_names)


def _tree_zeros_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _rules_for(mesh):
    return MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES


def _batch_pspec(batch_tree, daxes):
    """(M, B, ...) leaves: shard the global-batch dim over the data axes."""
    return jax.tree.map(lambda x: P(None, daxes) if x.ndim >= 2 else P(), batch_tree)


def _check_stats_impl(stats_impl: str, variance_impl: str = "scalar"):
    if stats_impl not in ("tree", "flat"):
        raise ValueError(f"stats_impl must be 'tree' or 'flat', got {stats_impl!r}")
    if stats_impl == "flat" and variance_impl == "paper":
        raise ValueError("variance_impl='paper' (full-vector all-reduce "
                         "baseline) has no flat-buffer path; use stats_impl='tree'")


def _opt_like_for(stats_impl: str, params_like):
    """Abstract optimizer state: pytree moments ('tree') or the DESIGN §9
    flat bucketed buffers ('flat')."""
    init = init_adamw_flat if stats_impl == "flat" else init_adamw
    return jax.eval_shape(init, params_like)


def _accumulate(model, params, batch, track_micro_sqnorm: bool):
    """lax.scan over the M stacked microbatches; returns (mean grads g,
    mean loss, mean aux, Σ_m ‖ĝ^m‖² if tracked, effective microbatch count).

    Microbatch contributions are weighted by their VALID-TOKEN count
    (labels >= 0), normalized by the total.  With the full, equal-sized
    microbatches of an unpadded batch this is exactly the old uniform mean;
    under the bucketed engine's padding (DESIGN §8) it makes padded slots —
    whole microbatches of `labels = -1` slots or a padded tail inside one —
    contribute nothing, so padded and unpadded batches produce identical
    loss and gradients."""

    def loss_fn(p, mb):
        loss, metrics = model.loss(p, mb)
        return loss, metrics

    def body(carry, mb):
        acc_g, acc_loss, acc_aux, acc_sq, acc_w, acc_m = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        w = jnp.sum(mb["labels"] >= 0).astype(jnp.float32)
        acc_g = jax.tree.map(lambda a, b: a + w * b.astype(jnp.float32), acc_g, g)
        if track_micro_sqnorm:
            # fully-padded microbatches carry no gradient draw: skip them in
            # the Σ_m ‖ĝ^m‖² used by the accumulation-variance estimator
            acc_sq = acc_sq + jnp.where(w > 0, tree_sqnorm(g), 0.0)
        return (acc_g, acc_loss + w * loss, acc_aux + w * metrics["aux"],
                acc_sq, acc_w + w, acc_m + (w > 0)), None

    init = (_tree_zeros_f32(params), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    (acc_g, acc_loss, acc_aux, acc_sq, acc_w, acc_m), _ = \
        jax.lax.scan(body, init, batch)
    denom = jnp.maximum(acc_w, 1.0)
    g = jax.tree.map(lambda x: x / denom, acc_g)
    return g, acc_loss / denom, acc_aux / denom, acc_sq, acc_m, acc_w


# --------------------------------------------------------- FSDP-Norm ----

def make_fsdp_norm_step(model, opt_cfg: AdamWConfig, mesh, *,
                        variance_impl: str = "scalar",
                        stats_impl: str = "tree",
                        sequence_parallel: bool = False,
                        params_like=None, jit: bool = True):
    """variance_impl: 'scalar' (pre-reduced 8-byte collective, DESIGN §7.1)
    or 'paper' (eq. 5 literal: all-reduce the full (g_j-g)² vector).

    stats_impl: 'tree' (leaf-by-leaf reference path) or 'flat' (DESIGN §9:
    bucketed flat buffers, single-pass fused statistics, one AdamW launch
    per bucket; optimizer state from `init_adamw_flat`)."""
    _check_stats_impl(stats_impl, variance_impl)
    daxes = data_axes(mesh)
    manual = _manual_axes(mesh, daxes)
    base = _rules_for(mesh)
    if sequence_parallel:
        base = with_sequence_parallel(base)
    rules = manual_data_rules(base, manual)

    def inner(params, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            g_j, loss, aux, _, _, w_j = _accumulate(model, params, batch, False)
            # valid-token-weighted mean over workers: equals plain pmean on
            # unpadded batches; exact under the engine's padding even when
            # the padded tail lands unevenly across workers (DESIGN §8)
            w_sum = jnp.maximum(jax.lax.psum(w_j, daxes), 1.0)
            g = jax.tree.map(
                lambda x: jax.lax.psum(x * w_j, daxes) / w_sum, g_j)
            if stats_impl == "flat":
                # single-pass fused pair + per-bucket fused AdamW; the ‖g‖²
                # from the statistics doubles as the clip norm (no re-read)
                var_l1, gsq = worker_variance_stats_flat(g_j, g, daxes)
            elif variance_impl == "paper":
                var_l1, gsq = paper_faithful_worker_variance(g_j, g, daxes)
            else:
                var_l1, gsq = worker_variance_stats(g_j, g, daxes)
            loss = jax.lax.psum(loss * w_j, daxes) / w_sum
            aux = jax.lax.psum(aux * w_j, daxes) / w_sum
            if stats_impl == "flat":
                new_params, new_opt, gnorm, _ = adamw_update_flat(
                    params, g, opt_state, opt_cfg, lr, grad_sqnorm=gsq)
            else:
                new_params, new_opt, gnorm = adamw_update(
                    params, g, opt_state, opt_cfg, lr)
        metrics = {"loss": loss, "aux": aux, "var_l1": var_l1,
                   "grad_sqnorm": gsq, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=False)
    opt_like = _opt_like_for(stats_impl, params_like)
    if stats_impl == "flat":
        # bucketed 1-D buffers: replicated (like the fully-manual params)
        o_specs = jax.tree.map(lambda _: P(), opt_like)
    else:
        o_specs = {"m": p_specs, "v": p_specs, "count": P()}

    def batch_specs(batch_like):
        return _batch_pspec(batch_like, daxes)

    def wrap(batch_like):
        sm = shard_map(
            inner, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params_like),
                      jax.tree.map(lambda _: P(), opt_like),
                      batch_specs(batch_like), P()),
            out_specs=(jax.tree.map(lambda _: P(), params_like),
                       jax.tree.map(lambda _: P(), opt_like),
                       {"loss": P(), "aux": P(), "var_l1": P(),
                        "grad_sqnorm": P(), "grad_norm": P()}),
            axis_names=set(manual), check_vma=False)
        if not jit:
            return sm
        return jax.jit(
            sm,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s),
                             batch_specs(batch_like),
                             is_leaf=lambda s: isinstance(s, P)),
                None),
            out_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                None),
            donate_argnums=(0, 1))

    return wrap, p_specs, o_specs


# -------------------------------------------------------- ACCUM-NORM ----

def make_accum_norm_step(model, opt_cfg: AdamWConfig, mesh, *,
                         stats_impl: str = "tree",
                         params_like=None, jit: bool = True):
    """Beyond-paper: pure-GSPMD step with full-mesh FSDP params; variance from
    accumulation microbatches (requires M >= 2 for a signal).

    stats_impl='flat' (DESIGN §9): the AdamW tail runs over bucketed flat
    buffers and its Σ‖g‖² kernel byproduct feeds the variance statistic and
    the grad_norm metric — zero extra gradient-sized passes.  Flat moment
    buffers are replicated (not FSDP-sharded); sharded flat buckets are a
    ROADMAP item, so 'tree' remains the default for model>memory meshes."""
    _check_stats_impl(stats_impl)
    daxes = data_axes(mesh)
    rules = _rules_for(mesh)
    J = num_workers(mesh)

    def step(params, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            # constrain the batch over data axes (GSPMD)
            batch = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, P(None, daxes)) if x.ndim >= 2 else x, batch)
            g, loss, aux, sq_sum, m_eff, _ = _accumulate(model, params, batch, True)
            if stats_impl == "flat":
                new_params, new_opt, gnorm, gsq = adamw_update_flat(
                    params, g, opt_state, opt_cfg, lr)
                var_l1, gsq = accum_variance_stats(sq_sum, g, m_eff, J, gsq=gsq)
            else:
                var_l1, gsq = accum_variance_stats(sq_sum, g, m_eff, J)
                new_params, new_opt, gnorm = adamw_update(
                    params, g, opt_state, opt_cfg, lr)
        metrics = {"loss": loss, "aux": aux, "var_l1": var_l1,
                   "grad_sqnorm": gsq, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=True)
    if stats_impl == "flat":
        opt_like = _opt_like_for(stats_impl, params_like)
        o_specs = jax.tree.map(lambda _: P(), opt_like)
    else:
        o_specs = {"m": p_specs, "v": p_specs, "count": P()}

    def wrap(batch_like):
        if not jit:
            return step
        return jax.jit(
            step,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda x: NamedSharding(mesh, P(None, daxes))
                             if x.ndim >= 2 else NamedSharding(mesh, P()),
                             batch_like),
                None),
            donate_argnums=(0, 1))

    return wrap, p_specs, o_specs
