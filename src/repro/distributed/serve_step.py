"""Serving steps under GSPMD: prefill (full-sequence forward producing the KV
cache) and decode (one token against the cache).  These are what the
decode_32k / long_500k dry-run shapes lower."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.distributed.params import param_pspecs, cache_pspecs
from repro.distributed.sharding import (
    DEFAULT_RULES, MULTIPOD_RULES, ShardingRules, use_sharding_rules)
from repro.launch.mesh import data_axes, num_workers


def _serve_rules(mesh, batch: int) -> ShardingRules:
    base = MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES
    if batch % num_workers(mesh) != 0:
        # batch not shardable over the data axes (long_500k b=1): replicate it
        return ShardingRules(rules={**base.rules, "batch": None})
    return base


def make_decode_step(model, mesh, *, batch: int, ring: bool = False,
                     params_like=None, jit: bool = True):
    rules = _serve_rules(mesh, batch)
    daxes = data_axes(mesh)
    batch_ok = batch % num_workers(mesh) == 0

    def step(params, cache, tokens, pos):
        with use_sharding_rules(rules, mesh):
            logits, new_cache = model.decode_step(params, cache, tokens, pos,
                                                  ring=ring)
        return logits, new_cache

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=False)

    def wrap(cache_like):
        if not jit:
            return step
        c_specs = cache_pspecs(cache_like, mesh, batch_divisible=batch_ok)
        tok_sharding = NamedSharding(mesh, P(daxes) if batch_ok else P())
        return jax.jit(
            step,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                tok_sharding, None),
            donate_argnums=(1,))

    return wrap, p_specs


def make_prefill(model, mesh, *, batch: int, params_like=None, jit: bool = True):
    rules = _serve_rules(mesh, batch)
    daxes = data_axes(mesh)
    batch_ok = batch % num_workers(mesh) == 0

    def run(params, batch_inputs):
        with use_sharding_rules(rules, mesh):
            return model.prefill(params, batch_inputs)

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=False)

    def wrap(batch_like):
        if not jit:
            return run
        b_shardings = jax.tree.map(
            lambda x: NamedSharding(mesh, P(daxes) if batch_ok else P())
            if x.ndim >= 1 else NamedSharding(mesh, P()), batch_like)
        return jax.jit(run, in_shardings=(
            jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                         is_leaf=lambda s: isinstance(s, P)),
            b_shardings))

    return wrap, p_specs
