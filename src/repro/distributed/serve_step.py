"""Serving steps under GSPMD: prefill (full-sequence forward producing the KV
cache) and decode (one token against the cache).  These are what the
decode_32k / long_500k dry-run shapes lower.

`make_slot_decode_step` + the slot-cache primitives below are the
continuous-batching serving tier's device half (DESIGN §11): ONE resident
KV buffer sized for the top request-batch rung, rung-sliced compiled decode
steps over its leading rows, and slot reset/compaction ops so requests
reuse slots without a reallocation or recompile."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.distributed.params import param_pspecs, cache_pspecs
from repro.distributed.sharding import (
    DEFAULT_RULES, MULTIPOD_RULES, ShardingRules, use_sharding_rules)
from repro.launch.mesh import data_axes, num_workers


def _serve_rules(mesh, batch: int) -> ShardingRules:
    base = MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES
    if batch % num_workers(mesh) != 0:
        # batch not shardable over the data axes (long_500k b=1): replicate it
        return ShardingRules(rules={**base.rules, "batch": None})
    return base


def make_decode_step(model, mesh, *, batch: int, ring: bool = False,
                     params_like=None, jit: bool = True):
    rules = _serve_rules(mesh, batch)
    daxes = data_axes(mesh)
    batch_ok = batch % num_workers(mesh) == 0

    def step(params, cache, tokens, pos):
        with use_sharding_rules(rules, mesh):
            logits, new_cache = model.decode_step(params, cache, tokens, pos,
                                                  ring=ring)
        return logits, new_cache

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=False)

    def wrap(cache_like):
        if not jit:
            return step
        c_specs = cache_pspecs(cache_like, mesh, batch_divisible=batch_ok)
        tok_sharding = NamedSharding(mesh, P(daxes) if batch_ok else P())
        return jax.jit(
            step,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                tok_sharding, None),
            donate_argnums=(1,))

    return wrap, p_specs


def make_prefill(model, mesh, *, batch: int, params_like=None, jit: bool = True):
    rules = _serve_rules(mesh, batch)
    daxes = data_axes(mesh)
    batch_ok = batch % num_workers(mesh) == 0

    def run(params, batch_inputs):
        with use_sharding_rules(rules, mesh):
            return model.prefill(params, batch_inputs)

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=False)

    def wrap(batch_like):
        if not jit:
            return run
        b_shardings = jax.tree.map(
            lambda x: NamedSharding(mesh, P(daxes) if batch_ok else P())
            if x.ndim >= 1 else NamedSharding(mesh, P()), batch_like)
        return jax.jit(run, in_shardings=(
            jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                         is_leaf=lambda s: isinstance(s, P)),
            b_shardings))

    return wrap, p_specs


# ------------------------------------------------- resident slot caches ----

# leading batch ("slot") axis of each decode-cache group: prefix-layer
# entries are (b, ...), scanned entries carry the repeat axis first
_SLOT_AXIS = {"prefix": 0, "scanned": 1, "cross_prefix": 0, "cross_scanned": 1}


def _map_slots(cache: dict, fn):
    """Apply `fn(leaf, slot_axis)` over every leaf of a decode cache."""
    return {k: jax.tree.map(lambda x: fn(x, _SLOT_AXIS[k]), sub)
            for k, sub in cache.items()}


def slice_slots(cache: dict, n: int) -> dict:
    """The first `n` slot rows of every cache leaf (static slice)."""
    return _map_slots(
        cache, lambda x, ax: jax.lax.slice_in_dim(x, 0, n, axis=ax))


def update_slots(full: dict, sub: dict, n: int) -> dict:
    """Write an updated `n`-row sub-cache back into rows [0, n) of the
    resident buffer; rows >= n (free or other-rung slots) are untouched."""
    return {k: jax.tree.map(
        lambda f, s, ax=_SLOT_AXIS[k]: jax.lax.dynamic_update_slice_in_dim(
            f, s.astype(f.dtype), 0, axis=ax),
        full[k], new) for k, new in sub.items()}


def move_slot(cache: dict, src, dst) -> dict:
    """Copy slot row `src` over slot row `dst` (compaction after a request
    completes: the highest active slot backfills the freed one).  `src` and
    `dst` are traced scalars — ONE compile serves every (src, dst) pair."""
    def mv(x, ax):
        row = jax.lax.dynamic_slice_in_dim(x, src, 1, axis=ax)
        return jax.lax.dynamic_update_slice_in_dim(x, row, dst, axis=ax)
    return _map_slots(cache, mv)


def reset_slot(cache: dict, slot) -> dict:
    """Zero slot row `slot` (admission: recurrent states need a fresh
    carry; attention rows are overwritten position-by-position as the new
    request advances, but zeroing keeps every cache kind uniform)."""
    def rz(x, ax):
        zero = jnp.zeros(
            x.shape[:ax] + (1,) + x.shape[ax + 1:], x.dtype)
        return jax.lax.dynamic_update_slice_in_dim(x, zero, slot, axis=ax)
    return _map_slots(cache, rz)


def make_slot_decode_step(model, mesh, *, max_slots: int, params_like=None,
                          jit: bool = True):
    """Rung-sliced decode over a resident slot cache (DESIGN §11).

    The KV cache is allocated ONCE at the ladder's top rung (`max_slots`
    rows) and never reallocated.  The returned builder compiles one step
    per ACTIVE rung `b`: slice rows [0, b) out of every cache leaf, decode
    one token per row at PER-SLOT positions (each in-flight request lives
    on its own timeline), greedily pick the next token, and write the
    updated rows back.  The resident buffer is donated through, so a rung
    change moves zero cache bytes — and once the rung's executable is warm,
    compiles nothing.

    Returns (wrap, p_specs, c_specs_fn): `wrap(b, cache_like)` -> jitted
    `step(params, cache, tokens (b,), pos (b,)) -> (next_tok (b,), cache)`.
    """
    rules = _serve_rules(mesh, max_slots)
    daxes = data_axes(mesh)
    workers = num_workers(mesh)
    batch_ok = max_slots % workers == 0

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(params_like, mesh, fsdp=False)

    def cache_specs(cache_like):
        return cache_pspecs(cache_like, mesh, batch_divisible=batch_ok)

    def wrap(b: int, cache_like):
        if not (1 <= b <= max_slots):
            raise ValueError(f"rung {b} outside resident pool [1, {max_slots}]")

        def step(params, cache, tokens, pos):
            sub = slice_slots(cache, b)
            with use_sharding_rules(rules, mesh):
                logits, new_sub = model.decode_step(params, sub, tokens, pos)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, update_slots(cache, new_sub, b)

        if not jit:
            return step
        c_specs = cache_specs(cache_like)
        tok_sharding = NamedSharding(
            mesh, P(daxes) if (batch_ok and b % workers == 0) else P())
        return jax.jit(
            step,
            in_shardings=(
                jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                             is_leaf=lambda s: isinstance(s, P)),
                tok_sharding, tok_sharding),
            donate_argnums=(1,))

    return wrap, p_specs, cache_specs
