"""Multi-host warmup coordination + persistent compile cache (DESIGN §8.1).

The bucketed engine makes a batch increase a cache hit on ONE host; on a
multi-host mesh that is not enough — the paper's efficiency case collapses
unless the rung transition is a cache hit on EVERY host, at the SAME step.
Three failure modes motivate this module:

* hosts entering a new rung's executable at different times stall the whole
  fleet on the slowest compile (collectives block until everyone arrives);
* each host *guessing* its own warmup target can diverge (e.g. after a
  restart, or any nondeterminism on the controller inputs) — then some hosts
  warm the wrong rung and pay a foreground compile at the transition;
* one host's background warmup failing while the others succeed leaves the
  fleet split between an AOT executable and a synchronous build.

`Coordinator` is the small protocol the engine consumes:

* ``barrier(name)``      — rung-entry barrier: returns the seconds THIS host
                           waited for the fleet (``EngineStats.barrier_wait_s``).
* ``agree(topic, p)``    — warmup agreement: every host proposes its next
                           rung; the leader's (rank 0) proposal wins and is
                           returned to everyone.  A host whose local proposal
                           differs counts a desync and warms the agreed rung.
* ``broadcast_failure``  / ``poll_failures`` — one host's warmup failure
                           downgrades ALL hosts to the synchronous-build
                           fallback coherently (nobody keeps waiting on a
                           warmup that will never land elsewhere).

Implementations:

* `NoOpCoordinator`      — single host; every operation is free.
* `FileCoordinator`      — a shared directory (NFS on real clusters, tmpdir
                           under ``--xla_force_host_platform_device_count``
                           subprocess tests).  Barriers are rank files in a
                           per-(name, generation) directory; agreement is an
                           atomic write-once file from the leader; failures
                           are marker files.  Restart semantics: barrier
                           files persist, so a restarted worker re-running
                           the same deterministic step sequence sails
                           through barriers the fleet already passed and
                           catches up to the live one.
* `DistributedCoordinator` — `jax.distributed` runs: barriers double as the
                           failure exchange (one `process_allgather` carries
                           each host's failed-rung tags), agreement is
                           `broadcast_one_to_all`.

The **persistent compile cache** half (`enable_persistent_cache`) wires
`jax.config`'s compilation-cache directory for the job, keyed by JAX
version + backend so restarted or late-joining workers reuse the fleet's
executables while incompatible toolchains never collide on an entry.  A
process-wide monitoring listener counts disk-cache hits
(`/jax/compilation_cache/cache_hits`) so `EngineStats` can distinguish a
compile served from disk from a fresh XLA build.
"""

from __future__ import annotations

import json
import os
import re
import threading
import time
import zlib

import numpy as np

from repro.testing.faults import fault_point


class CoordinationError(TimeoutError):
    """A coordination operation failed with structured blame: which ranks
    never arrived, and which of those are provably DEAD (their liveness
    heartbeat went stale after having been seen).  Subclasses TimeoutError
    so pre-liveness callers that caught the bare timeout keep working.

    The train driver catches this to checkpoint-and-exit cleanly instead of
    hanging the surviving ranks (DESIGN §12)."""

    def __init__(self, message: str, *, missing=(), dead=()):
        super().__init__(message)
        self.missing_ranks = tuple(missing)
        self.dead_ranks = tuple(dead)


def _blame(missing, dead) -> str:
    parts = []
    if missing:
        parts.append(f"missing ranks: {sorted(missing)}")
    if dead:
        parts.append(f"dead ranks (stale heartbeat): {sorted(dead)}")
    return "; ".join(parts) if parts else "all ranks present"


# ------------------------------------------------------------ protocol ----

class Coordinator:
    """What the bucketed engine needs from a multi-host rendezvous layer."""

    rank: int = 0
    world: int = 1

    def barrier(self, name: str, timeout: float | None = None) -> float:
        """Block until all `world` hosts reach `name`; return seconds waited."""
        raise NotImplementedError

    def agree(self, topic: str, payload: str) -> str:
        """Return the leader's `payload` for `topic` on every host."""
        raise NotImplementedError

    def broadcast_failure(self, tag: str) -> None:
        """Mark `tag` (a rung key digest) as failed fleet-wide."""
        raise NotImplementedError

    def poll_failures(self) -> frozenset:
        """Tags any host has marked failed (non-blocking; may lag until the
        next synchronization point on collective-backed impls)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


class NoOpCoordinator(Coordinator):
    """Single-host: barriers are free, agreement echoes the proposal."""

    def barrier(self, name, timeout=None):
        return 0.0

    def agree(self, topic, payload):
        return payload

    def broadcast_failure(self, tag):
        pass

    def poll_failures(self):
        return frozenset()


# ------------------------------------------------------ file coordinator ----

def _fs_safe(name: str) -> str:
    """Filesystem-safe, collision-free token for an arbitrary name."""
    stem = re.sub(r"[^A-Za-z0-9_.x-]", "_", name)[:48]
    return f"{stem}-{zlib.crc32(name.encode()) & 0xFFFFFFFF:08x}"


class FileCoordinator(Coordinator):
    """Shared-directory rendezvous for multi-process (one JAX process per
    host) runs: subprocess tests under `--xla_force_host_platform_device_count`
    and real fleets with a shared filesystem.

    Every operation is lock-free on the consumer side: writers create files
    atomically (`os.replace` from a rank-private temp), readers poll.  The
    directory is append-only during a run — barrier generations, agreement
    topics and failure markers all get fresh paths — so a slow host can
    never miss an event that faster hosts already consumed.

    `run_id` namespaces the directory per job (`root/<run_id>/...`): a
    DIFFERENT job pointed at a reused coordination dir lands in its own
    namespace instead of silently sailing through the previous run's
    barrier files and replaying its write-once agreement decisions.
    Within one run_id, persistence is the restart contract: a restarted
    worker re-running the same deterministic step sequence skips barriers
    the fleet already passed and catches up to the live one.  Re-running
    an IDENTICAL job from scratch should use a fresh root.

    Liveness (DESIGN §12): a daemon thread refreshes ``hb/<rank>`` every
    `heartbeat_s`; a rank whose heartbeat was seen but has gone stale by
    more than `dead_after` seconds is DEAD.  A barrier whose missing ranks
    are all dead fails fast with a `CoordinationError` naming them instead
    of burning the full timeout, and every timeout names the missing/dead
    ranks rather than just a count.  A rank that never wrote a heartbeat is
    only *missing* (it may still be launching), so slow joiners get the
    whole timeout.  Polling backs off exponentially from `poll_s` to
    `poll_max_s` so fleet-scale shared filesystems aren't hammered at 200
    stats/s per rank for long waits.
    """

    def __init__(self, root: str, rank: int, world: int, *,
                 timeout: float = 120.0, poll_s: float = 0.005,
                 poll_max_s: float = 0.05, heartbeat_s: float | None = None,
                 dead_after: float | None = None, run_id: str = ""):
        if world < 1 or not (0 <= rank < world):
            raise ValueError(f"bad coordinator geometry rank={rank} world={world}")
        self.root = os.path.abspath(
            os.path.join(root, _fs_safe(run_id)) if run_id else root)
        self.rank, self.world = rank, world
        self.timeout, self.poll_s = timeout, poll_s
        self.poll_max_s = max(poll_max_s, poll_s)
        self.heartbeat_s = (heartbeat_s if heartbeat_s is not None else float(
            os.environ.get("REPRO_COORD_HEARTBEAT_S", "1.0")))
        self.dead_after = (dead_after if dead_after is not None else float(
            os.environ.get("REPRO_COORD_DEAD_AFTER_S",
                           str(10.0 * self.heartbeat_s))))
        self._gens: dict[str, int] = {}     # per-name barrier generation
        self._hb_dir = os.path.join(self.root, "hb")
        os.makedirs(self._hb_dir, exist_ok=True)
        self._stop = threading.Event()
        self._beat()                         # visible before any barrier
        self._hb_thread = threading.Thread(
            target=self._heartbeat_loop, name=f"coord-hb-{rank}", daemon=True)
        self._hb_thread.start()

    # ------------------------------------------------------------ liveness --

    def _beat(self) -> None:
        self._atomic_write(os.path.join(self._hb_dir, str(self.rank)),
                           repr(time.time()))

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_s):
            try:
                self._beat()
            except OSError:          # transient FS hiccup: stale beats are
                continue             # what the next refresh repairs

    def dead_ranks(self) -> frozenset:
        """Ranks whose heartbeat was SEEN but is now stale by > dead_after
        (started, then died/hung).  Never-seen ranks are not here — they may
        still be launching."""
        now = time.time()
        dead = set()
        for r in range(self.world):
            if r == self.rank:
                continue
            p = os.path.join(self._hb_dir, str(r))
            try:
                if now - os.path.getmtime(p) > self.dead_after:
                    dead.add(r)
            except OSError:
                continue             # no heartbeat yet: unknown, not dead
        return frozenset(dead)

    def close(self) -> None:
        self._stop.set()
        if self._hb_thread.is_alive():
            self._hb_thread.join(timeout=2 * self.heartbeat_s + 1.0)

    # ---------------------------------------------------------- primitives --

    def _atomic_write(self, path: str, content: str) -> None:
        tmp = f"{path}.tmp{self.rank}"
        with open(tmp, "w") as f:
            f.write(content)
        os.replace(tmp, path)

    def _poll_wait(self, waited_polls: int) -> None:
        """Exponential backoff: 5 ms doubling to the 50 ms cap, so a long
        barrier wait costs ~20 stats/s per rank instead of 200."""
        time.sleep(min(self.poll_s * (2 ** min(waited_polls, 16)),
                       self.poll_max_s))

    def barrier(self, name, timeout=None):
        timeout = self.timeout if timeout is None else timeout
        fault_point("coord.barrier", name=name, rank=self.rank)
        gen = self._gens[name] = self._gens.get(name, 0) + 1
        d = os.path.join(self.root, "barrier", f"{_fs_safe(name)}.{gen}")
        os.makedirs(d, exist_ok=True)
        self._atomic_write(os.path.join(d, str(self.rank)), "")
        t0 = time.monotonic()
        polls = 0
        while True:
            present = set()
            for f in os.listdir(d):
                try:                 # skip in-flight .tmp<rank> writes
                    present.add(int(f))
                except ValueError:
                    continue
            if len(present) >= self.world:
                return time.monotonic() - t0
            missing = set(range(self.world)) - present
            dead = self.dead_ranks() & missing
            timed_out = time.monotonic() - t0 > timeout
            if timed_out or (missing and missing <= dead):
                # every missing rank provably died: fail fast — waiting the
                # rest of the timeout cannot change the outcome
                raise CoordinationError(
                    f"coordination barrier {name!r} (generation {gen}): "
                    f"{len(present)}/{self.world} hosts arrived"
                    + (f" within {timeout:.1f}s" if timed_out else
                       " and every missing rank's heartbeat is stale")
                    + f" — {_blame(missing, dead)}; coordination dir: "
                    f"{self.root}", missing=missing, dead=dead)
            self._poll_wait(polls)
            polls += 1

    def agree(self, topic, payload):
        d = os.path.join(self.root, "agree")
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, _fs_safe(topic))
        if self.rank == 0:
            # write-once: a restarted leader must republish the SAME value
            # (the topic stream is deterministic), never clobber a decision
            # followers may have consumed
            if not os.path.exists(path):
                self._atomic_write(path, payload)
            with open(path) as f:
                return f.read()
        t0 = time.monotonic()
        polls = 0
        while not os.path.exists(path):
            leader_dead = 0 in self.dead_ranks()
            if time.monotonic() - t0 > self.timeout or leader_dead:
                raise CoordinationError(
                    f"warmup agreement {topic!r}: leader (rank 0) published "
                    "nothing"
                    + (" and its heartbeat is stale" if leader_dead else
                       f" within {self.timeout:.1f}s")
                    + f" (coordination dir: {self.root})",
                    missing=(0,), dead=((0,) if leader_dead else ()))
            self._poll_wait(polls)
            polls += 1
        with open(path) as f:
            return f.read()

    def broadcast_failure(self, tag):
        d = os.path.join(self.root, "fail")
        os.makedirs(d, exist_ok=True)
        self._atomic_write(os.path.join(d, _fs_safe(tag)), tag)

    def poll_failures(self):
        d = os.path.join(self.root, "fail")
        if not os.path.isdir(d):
            return frozenset()
        tags = set()
        for entry in os.listdir(d):
            if entry.endswith(f".tmp{self.rank}"):
                continue
            try:
                with open(os.path.join(d, entry)) as f:
                    tags.add(f.read())
            except OSError:      # another rank's temp file vanished mid-list
                continue
        return frozenset(tags)


# ----------------------------------------------- jax.distributed backend ----

_PAYLOAD_BYTES = 1024


def _pack_str(s: str, n: int = _PAYLOAD_BYTES) -> np.ndarray:
    b = s.encode()
    if len(b) > n:
        raise ValueError(f"coordination payload too large ({len(b)} > {n})")
    arr = np.zeros(n, np.uint8)
    arr[: len(b)] = np.frombuffer(b, np.uint8)
    return arr


def _unpack_str(arr) -> str:
    return bytes(np.asarray(arr, np.uint8)).rstrip(b"\0").decode()


class DistributedCoordinator(Coordinator):
    """`jax.distributed`-backed coordination: barriers are a
    `process_allgather` that doubles as the failure exchange (each host
    contributes its locally-failed rung tags, so by the time anyone crosses
    a rung-entry barrier the whole fleet shares one failure view), and
    agreement is `broadcast_one_to_all` from process 0.

    `poll_failures` is non-blocking by design: it returns the view as of the
    last barrier plus this host's own failures — exactly the point where the
    engine consumes it (failures are checked AT rung entry, right next to
    the barrier that refreshes them).

    Timeouts: unlike the file coordinator, the collectives here cannot take
    a per-call deadline — a dead host surfaces through the `jax.distributed`
    runtime's own collective/heartbeat timeouts (configured at
    `jax.distributed.initialize`), not through `--coord-timeout`, which this
    backend ignores."""

    def __init__(self, timeout: float = 120.0):
        from repro.compat import process_count, process_index
        self.rank = process_index()
        self.world = process_count()
        del timeout   # accepted for factory symmetry; see class docstring
        self._local: set[str] = set()
        self._known: set[str] = set()

    def barrier(self, name, timeout=None):
        from jax.experimental import multihost_utils
        fault_point("coord.barrier", name=name, rank=self.rank)
        t0 = time.monotonic()
        try:
            rows = multihost_utils.process_allgather(
                _pack_str(json.dumps(sorted(self._local))))
        except Exception as e:
            # the runtime's collective/heartbeat machinery already decided a
            # peer is gone; re-raise TYPED so the train driver's
            # checkpoint-and-exit path triggers (it cannot name the rank —
            # the runtime's error text usually does)
            raise CoordinationError(
                f"distributed barrier {name!r} failed across "
                f"{self.world} processes (a peer likely died): {e}") from e
        for row in np.atleast_2d(rows):
            self._known.update(json.loads(_unpack_str(row) or "[]"))
        return time.monotonic() - t0

    def agree(self, topic, payload):
        from jax.experimental import multihost_utils
        try:
            out = multihost_utils.broadcast_one_to_all(_pack_str(payload))
        except Exception as e:
            raise CoordinationError(
                f"distributed agreement {topic!r} failed (leader or a peer "
                f"died mid-broadcast): {e}", missing=(0,)) from e
        return _unpack_str(out)

    def broadcast_failure(self, tag):
        self._local.add(tag)

    def poll_failures(self):
        return frozenset(self._known | self._local)


# -------------------------------------------------------------- factory ----

def make_coordinator(kind: str, *, root: str = "", rank: int = -1,
                     world: int = 0, timeout: float = 120.0,
                     run_id: str = ""):
    """Resolve `--coord={none,file,distributed}` into a Coordinator (or None
    for `none` — the engine's coordination hooks vanish entirely, bit-
    identical to the uncoordinated single-host engine).

    `file` geometry resolves from explicit args first, then the
    `REPRO_COORD_RANK` / `REPRO_COORD_WORLD` environment (how the subprocess
    tests and the CI smoke launch per-host processes); `run_id` namespaces
    the shared directory per job (see FileCoordinator)."""
    if kind in ("none", "", None):
        return None
    if kind == "file":
        if not root:
            raise ValueError("--coord=file needs --coord-dir (a directory "
                             "shared by every host)")
        rank = rank if rank >= 0 else int(os.environ.get("REPRO_COORD_RANK", "0"))
        world = world or int(os.environ.get("REPRO_COORD_WORLD", "1"))
        return FileCoordinator(root, rank, world, timeout=timeout,
                               run_id=run_id)
    if kind == "distributed":
        return DistributedCoordinator(timeout=timeout)
    raise ValueError(f"unknown coordinator kind {kind!r} "
                     "(expected none|file|distributed)")


# ------------------------------------------- persistent compile cache ----

_disk_hits = 0
_listener_lock = threading.Lock()
_listener_installed = False


def _install_hit_listener() -> None:
    global _listener_installed
    with _listener_lock:
        if _listener_installed:
            return
        import jax

        def _on_event(name: str, **kw) -> None:
            global _disk_hits
            if name == "/jax/compilation_cache/cache_hits":
                with _listener_lock:
                    _disk_hits += 1

        jax.monitoring.register_event_listener(_on_event)
        _listener_installed = True


def disk_cache_hits() -> int:
    """Process-wide count of compiles served from the persistent disk cache
    (0 until `enable_persistent_cache` installs the monitoring listener)."""
    with _listener_lock:
        return _disk_hits


def enable_persistent_cache(cache_dir: str) -> str:
    """Point JAX's persistent compilation cache at `cache_dir` for this job.

    The actual directory is keyed by JAX version and backend platform —
    restarted or late-joining workers of the same job resolve to the SAME
    key and deserialize the fleet's executables instead of recompiling,
    while a toolchain bump or a CPU-smoke run never poisons the TPU fleet's
    entries (XLA additionally content-hashes every executable, so entries
    are safe against stale HLO).  Thresholds are zeroed so even smoke-scale
    steps persist — the multi-host tests restart an engine and assert a
    disk hit.  Returns the resolved directory."""
    import jax
    path = os.path.join(cache_dir,
                        f"jax{jax.__version__}-{jax.default_backend()}")
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    _install_hit_listener()
    return path


__all__ = [
    "CoordinationError", "Coordinator", "NoOpCoordinator", "FileCoordinator",
    "DistributedCoordinator", "make_coordinator",
    "enable_persistent_cache", "disk_cache_hits",
]
