"""Flat gradient buffers: dtype-homogeneous bucketed views of a pytree
(DESIGN §9 "Flat gradient buffers & single-pass statistics").

The per-step statistics+update tail (norm-test reductions + AdamW) used to
walk the gradient/param/moment pytrees leaf-by-leaf: O(leaves) kernel
launches / XLA ops per step, and each statistic its own full pass over
gradient-sized data.  `FlatLayout` precomputes a static packing of the tree
into a few contiguous buffers so the whole tail runs as a handful of fused
kernels instead:

* leaves are grouped by **dtype** (a buffer is dtype-homogeneous — mixed
  f32/bf16 params never share a buffer);
* each group is split into **buckets** of ~`bucket_bytes` (PyTorch-DDP
  style): the op count scales with total bytes, not leaf count, while
  buckets stay small enough that XLA/CPU can still schedule them
  concurrently and a TPU grid covers each with one launch;
* every leaf records a static `(buffer_index, offset, size, shape)` slot, so
  `flatten`/`unflatten` are pure reshape+concat/slice — bit-exact round
  trips, no dtype casts;
* with `shard_divisor=J` each bucket is zero-padded to a J-divisible size
  (per-bucket `pad` recorded in `buffer_pads`), so the buffers carry real
  data-axis `PartitionSpec`s over a J-worker mesh instead of being
  replicated — the padded tail never overlaps a slot, contributes nothing
  to any reduction, and round trips bit-exactly;
* `unflatten_for_grad` is the flat-RESIDENT entry point (DESIGN §10): a
  differentiable unflatten whose VJP packs the leaf cotangents straight
  back into per-bucket buffers, so a loss composed with it yields
  gradients that are *born flat* — no materialized gradient pytree, no
  per-step re-pack.  `FlatParams` is the host-side residency wrapper.

The layout is a trace-time Python object (shapes/dtypes only): build it from
concrete arrays or `ShapeDtypeStruct`s, reuse it across congruent trees
(grads, moments, params of the same structure).  Gradients produced by the
train steps are all-f32 regardless of param dtype — they flatten through the
same slots into f32 buffers; `flatten` only requires each *bucket's* leaves
to agree on the dtype of the tree actually being flattened.

Packing is the flat path's per-step entry cost, so it is instrumented:
every layout entry point binds a zero-cost marker primitive
(`layout_marker_p`, kind = "pack" / "unflatten" / "adjoint") on its
buffers, so the events survive into the jaxpr — visible *inside* jit,
scan, shard_map, and custom_vjp — where `repro.analysis.jaxpr_check`
counts them.  Tracing one flat train step must show the mean gradient
packed exactly ONCE (the flat-tail double-pack regression guard).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.extend.core import Primitive
from jax.interpreters import mlir

# ~4 MiB of f32 per bucket on TPU: big enough that per-launch overhead
# vanishes, small enough for VMEM-friendly grids.
DEFAULT_BUCKET_BYTES = 4 << 20
# XLA CPU runs elementwise fusion loops single-threaded (only inter-op
# concurrency uses the thread pool), so one big bucket SERIALIZES the tail
# that the per-leaf tree path parallelizes across leaves for free — 128 KiB
# buckets restore thread-level parallelism (measured 4× on the fused AdamW
# at 0.5M params) while still collapsing op count well below leaf count.
CPU_BUCKET_BYTES = 128 << 10


def default_bucket_bytes() -> int:
    """Backend-resolved bucket size: per-launch grids want few big buckets
    (TPU), inter-op thread scheduling wants many small ones (CPU)."""
    from repro.kernels import _backend_is_tpu
    return DEFAULT_BUCKET_BYTES if _backend_is_tpu() else CPU_BUCKET_BYTES


@dataclass(frozen=True)
class Slot:
    """Where one leaf lives: `buffer[offset:offset+size].reshape(shape)`."""
    leaf_index: int          # position in jax.tree.flatten order
    buffer_index: int
    offset: int
    size: int
    shape: tuple


# ------------------------------------------------ layout marker primitive ----

# Identity primitive stamped on the buffer lists at every layout entry point
# so the *event* ("this step packs a tree here") survives into the jaxpr as a
# countable equation.  It lowers to nothing (the MLIR rule returns its
# operands), executes as identity when called with concrete arrays, and
# carries (kind, nleaves) as static eqn params for `repro.analysis`:
#
#   kind="pack"      — `flatten`: a materialized pytree entered the layout
#   kind="unflatten" — `unflatten` / `unflatten_for_grad` primal: buffers
#                      were sliced back out into a pytree view
#   kind="adjoint"   — the backward-pass pack (`unflatten_for_grad`'s VJP or
#                      the manual `pack_cotangents` transpose): NOT a
#                      host-level re-entry, accounted separately
layout_marker_p = Primitive("repro_layout_marker")
layout_marker_p.multiple_results = True
layout_marker_p.def_impl(lambda *bufs, kind, nleaves: list(bufs))
layout_marker_p.def_abstract_eval(lambda *bufs, kind, nleaves: list(bufs))
mlir.register_lowering(
    layout_marker_p, lambda ctx, *bufs, kind, nleaves: list(bufs))

# Identity is trivially linear and batchable — register both so the marker
# is transparent to any transform a caller wraps around the layout (vmapped
# per-sample stats, vjp through a plain `unflatten`).
jax.interpreters.ad.deflinear2(
    layout_marker_p, lambda cts, *bufs, kind, nleaves: list(cts))
jax.interpreters.batching.primitive_batchers[layout_marker_p] = (
    lambda args, dims, *, kind, nleaves:
        (layout_marker_p.bind(*args, kind=kind, nleaves=nleaves), list(dims)))


def _mark(buffers, kind: str, nleaves: int):
    """Bind the marker on a buffer list (identity).  Zero-buffer layouts
    (empty trees) have no operands to thread the eqn through — and nothing
    worth counting — so they are left unmarked."""
    if not buffers:
        return buffers
    return layout_marker_p.bind(*buffers, kind=kind, nleaves=nleaves)


class FlatLayout:
    """Static packing of a pytree into dtype-homogeneous bucketed buffers."""

    def __init__(self, treedef, slots, buffer_sizes, buffer_dtypes,
                 buffer_pads=None, shard_divisor: int = 1,
                 bucket_bytes: int | None = None):
        self.treedef = treedef
        self.slots = tuple(slots)                  # ordered by leaf_index
        self.buffer_sizes = tuple(buffer_sizes)    # INCLUDING shard padding
        self.buffer_dtypes = tuple(buffer_dtypes)  # the layout tree's dtypes
        self.buffer_pads = (tuple(buffer_pads) if buffer_pads is not None
                            else (0,) * len(buffer_sizes))
        self.shard_divisor = shard_divisor
        self.bucket_bytes = bucket_bytes           # the from_tree recipe knob
        self.num_buffers = len(buffer_sizes)
        self.num_leaves = len(self.slots)
        self.total_size = sum(buffer_sizes)
        self._unflat_grad = None                   # lazy custom-vjp unflatten

    def _cmp_key(self):
        return (self.treedef, self.slots, self.buffer_sizes,
                self.buffer_dtypes, self.buffer_pads, self.shard_divisor)

    def __eq__(self, other):
        return (isinstance(other, FlatLayout)
                and self._cmp_key() == other._cmp_key())

    def __hash__(self):
        return hash(self._cmp_key())

    @classmethod
    def from_tree(cls, tree, bucket_bytes: int | None = None,
                  shard_divisor: int = 1):
        """Build from concrete arrays or ShapeDtypeStructs.  Leaves are
        packed first-seen-dtype-major, then greedily into buckets that close
        once they reach `bucket_bytes` (backend-resolved default, see
        `default_bucket_bytes`; a single oversized leaf is its own bucket —
        leaves never straddle buckets).  Each closed bucket is padded up to
        a `shard_divisor`-divisible size (zero-filled on `flatten`, never
        referenced by any slot) so the buffers shard evenly over a
        `shard_divisor`-worker data axis."""
        if bucket_bytes is None:
            bucket_bytes = default_bucket_bytes()
        if shard_divisor < 1:
            raise ValueError(f"shard_divisor must be >= 1, got {shard_divisor}")
        leaves, treedef = jax.tree.flatten(tree)
        by_dtype: dict = {}
        for i, leaf in enumerate(leaves):
            by_dtype.setdefault(jnp.dtype(leaf.dtype), []).append(i)

        slots = {}
        sizes, pads, dtypes = [], [], []

        def close(data_size, dt):
            pad = (-data_size) % shard_divisor
            sizes.append(data_size + pad)
            pads.append(pad)
            dtypes.append(dt)

        for dt, idxs in by_dtype.items():
            target = max(1, bucket_bytes // max(dt.itemsize, 1))
            cur_off = 0
            open_bucket = False
            for i in idxs:
                size = math.prod(leaves[i].shape) if leaves[i].shape else 1
                if open_bucket and cur_off and cur_off + size > target:
                    close(cur_off, dt)
                    cur_off = 0
                    open_bucket = False
                if not open_bucket:
                    buf_idx = len(sizes)
                    open_bucket = True
                slots[i] = Slot(i, buf_idx, cur_off, size,
                                tuple(leaves[i].shape))
                cur_off += size
            if open_bucket:
                # cur_off may be 0 here (a bucket of only size-0 leaves) —
                # still a real bucket, or its slots would dangle
                close(cur_off, dt)
        ordered = [slots[i] for i in range(len(leaves))]
        return cls(treedef, ordered, sizes, dtypes, pads, shard_divisor,
                   bucket_bytes)

    # ------------------------------------------------------------ pack ----

    def flatten(self, tree):
        """Pack a congruent tree into its buffers (list of 1-D arrays).

        Buffer dtype is taken from the tree being flattened, not the layout
        tree — e.g. f32 gradients of bf16 params pack into f32 buffers
        through the bf16 layout's slots.  All leaves landing in one bucket
        must agree on dtype.  Shard padding is zero-filled — bit-exact
        round trips, zero contribution to any sum/moment."""
        leaves = jax.tree.leaves(tree)
        if len(leaves) != self.num_leaves:
            raise ValueError(
                f"tree has {len(leaves)} leaves, layout expects {self.num_leaves}")
        return _mark(self._pack(leaves), "pack", self.num_leaves)

    def _pack(self, leaves):
        """Core packing (ravel + per-bucket concat + zero pad), shared by
        `flatten` and the `unflatten_for_grad` adjoint.  Binds no "pack"
        marker itself — callers that enter the flat layout from a
        materialized pytree go through `flatten`, which does."""
        parts: list = [[] for _ in range(self.num_buffers)]
        for slot, leaf in zip(self.slots, leaves):
            if tuple(leaf.shape) != slot.shape:
                raise ValueError(
                    f"leaf {slot.leaf_index} shape {tuple(leaf.shape)} != "
                    f"layout shape {slot.shape}")
            parts[slot.buffer_index].append((slot.offset, leaf))
        buffers = []
        for bi, plist in enumerate(parts):
            plist.sort(key=lambda t: t[0])
            ravels = [jnp.ravel(leaf) for _, leaf in plist]
            if len({r.dtype for r in ravels}) != 1:
                raise ValueError(
                    f"buffer {bi} mixes dtypes {sorted({str(r.dtype) for r in ravels})}")
            buf = ravels[0] if len(ravels) == 1 else jnp.concatenate(ravels)
            if self.buffer_pads[bi]:
                buf = jnp.pad(buf, (0, self.buffer_pads[bi]))
            buffers.append(buf)
        return buffers

    def unflatten(self, buffers):
        """Inverse of `flatten`: slice each leaf back out (bit-exact; the
        shard padding is never referenced by a slot)."""
        if len(buffers) != self.num_buffers:
            raise ValueError(
                f"got {len(buffers)} buffers, layout expects {self.num_buffers}")
        for bi, (buf, size) in enumerate(zip(buffers, self.buffer_sizes)):
            if buf.size != size:
                raise ValueError(
                    f"buffer {bi} has {buf.size} elements, layout expects {size}")
        buffers = _mark(list(buffers), "unflatten", self.num_leaves)
        leaves = [
            buffers[s.buffer_index][s.offset:s.offset + s.size].reshape(s.shape)
            for s in self.slots]
        return self.treedef.unflatten(leaves)

    # ------------------------------------------------- flat residency ----

    def unflatten_for_grad(self, buffers):
        """Differentiable unflatten for flat-RESIDENT parameters (DESIGN
        §10): forward is exactly `unflatten`, but the VJP is overridden so
        the leaf cotangents are packed straight into per-bucket buffers
        (one ravel+concat per bucket, shard pad zero-filled) instead of
        the generic slice adjoint XLA would emit for `unflatten` (a
        zero-pad of every leaf cotangent to full bucket size + an N-way
        add).  A loss composed with this function therefore yields
        gradients that are *born flat*: ``jax.grad(lambda bufs:
        loss(layout.unflatten_for_grad(bufs)))`` returns bucket buffers
        bit-identical to ``layout.flatten(jax.grad(loss)(tree))``.

        Takes (and differentiates w.r.t.) a tuple of buffers.  The
        explicit adjoint deliberately binds an "adjoint" marker, never a
        "pack": it replaces the autodiff transpose inside the backward
        pass — the per-step re-pack of a materialized gradient pytree is
        exactly the cost flat residency deletes."""
        if self._unflat_grad is None:
            @jax.custom_vjp
            def unflat(bufs):
                return self.unflatten(list(bufs))

            def fwd(bufs):
                return self.unflatten(list(bufs)), None

            def bwd(_, ct):
                bufs = self._pack(jax.tree.leaves(ct))
                return (tuple(_mark(bufs, "adjoint", self.num_leaves)),)

            unflat.defvjp(fwd, bwd)
            self._unflat_grad = unflat
        return self._unflat_grad(tuple(buffers))

    def pack_cotangents(self, ct_tree):
        """The pad-slice adjoint of `unflatten` applied manually: pack a
        cotangent tree into per-bucket buffers (dtype taken from the
        cotangents — e.g. f32 accumulators transpose through a bf16
        layout's slots into f32 buffers, exactly like `flatten` packs f32
        gradients of bf16 params; pads zero-filled).  `unflatten` is
        linear, so this IS its transpose for any cotangent; the train
        steps use it to transpose the whole accumulated gradient once per
        step without downcasting to the param dtype (which a dtype-strict
        `jax.vjp` would force).  Like `unflatten_for_grad`'s VJP, it
        binds an "adjoint" marker, never a "pack" — it is the autodiff
        transpose, not a host-level re-entry into the layout."""
        leaves = jax.tree.leaves(ct_tree)
        if len(leaves) != self.num_leaves:
            raise ValueError(
                f"cotangent tree has {len(leaves)} leaves, layout expects "
                f"{self.num_leaves}")
        return _mark(self._pack(leaves), "adjoint", self.num_leaves)

    # --------------------------------------------------------- helpers ----

    def zeros(self, dtype=jnp.float32):
        """Fresh zero buffers (moment-state initialization)."""
        return [jnp.zeros((n,), dtype) for n in self.buffer_sizes]


def flatten_tree(tree, bucket_bytes: int | None = None,
                 shard_divisor: int = 1):
    """One-shot convenience: (layout, buffers)."""
    layout = FlatLayout.from_tree(tree, bucket_bytes, shard_divisor)
    return layout, layout.flatten(tree)


@jax.tree_util.register_pytree_node_class
class FlatParams:
    """Host-side residency wrapper for flat-resident parameters (DESIGN §10):
    a `FlatLayout` plus the live bucket buffers.

    The train steps take and return the raw buffer tuple (`.buffers`) so
    nothing exotic crosses the shard_map/jit boundary; this wrapper owns the
    layout so the training loop, evaluation, and checkpointing can round-trip
    to the pytree view (`to_tree`, bit-exact) and rebuild the residency on a
    different backend bucket size (`from_tree`).  Registered as a pytree
    (buffers are children, the layout is static aux data) so `jax.tree.map`
    and friends treat it like any other container."""

    __slots__ = ("layout", "buffers")

    def __init__(self, layout: FlatLayout, buffers):
        self.layout = layout
        self.buffers = tuple(buffers)

    @classmethod
    def from_tree(cls, tree, bucket_bytes: int | None = None,
                  shard_divisor: int = 1):
        layout = FlatLayout.from_tree(tree, bucket_bytes, shard_divisor)
        return cls(layout, layout.flatten(tree))

    def to_tree(self):
        """The pytree view (bit-exact; slices, no casts)."""
        return self.layout.unflatten(list(self.buffers))

    def tree_flatten(self):
        return self.buffers, self.layout

    @classmethod
    def tree_unflatten(cls, layout, buffers):
        return cls(layout, buffers)


__all__ = ["FlatLayout", "FlatParams", "Slot", "flatten_tree",
           "layout_marker_p", "default_bucket_bytes", "DEFAULT_BUCKET_BYTES",
           "CPU_BUCKET_BYTES"]
