"""Flat gradient buffers: dtype-homogeneous bucketed views of a pytree
(DESIGN §9 "Flat gradient buffers & single-pass statistics").

The per-step statistics+update tail (norm-test reductions + AdamW) used to
walk the gradient/param/moment pytrees leaf-by-leaf: O(leaves) kernel
launches / XLA ops per step, and each statistic its own full pass over
gradient-sized data.  `FlatLayout` precomputes a static packing of the tree
into a few contiguous buffers so the whole tail runs as a handful of fused
kernels instead:

* leaves are grouped by **dtype** (a buffer is dtype-homogeneous — mixed
  f32/bf16 params never share a buffer);
* each group is split into **buckets** of ~`bucket_bytes` (PyTorch-DDP
  style): the op count scales with total bytes, not leaf count, while
  buckets stay small enough that XLA/CPU can still schedule them
  concurrently and a TPU grid covers each with one launch;
* every leaf records a static `(buffer_index, offset, size, shape)` slot, so
  `flatten`/`unflatten` are pure reshape+concat/slice — bit-exact round
  trips, no dtype casts.

The layout is a trace-time Python object (shapes/dtypes only): build it from
concrete arrays or `ShapeDtypeStruct`s, reuse it across congruent trees
(grads, moments, params of the same structure).  Gradients produced by the
train steps are all-f32 regardless of param dtype — they flatten through the
same slots into f32 buffers; `flatten` only requires each *bucket's* leaves
to agree on the dtype of the tree actually being flattened.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

# ~4 MiB of f32 per bucket: big enough that per-op dispatch overhead
# vanishes, small enough for concurrent scheduling and VMEM-friendly grids.
DEFAULT_BUCKET_BYTES = 4 << 20


@dataclass(frozen=True)
class Slot:
    """Where one leaf lives: `buffer[offset:offset+size].reshape(shape)`."""
    leaf_index: int          # position in jax.tree.flatten order
    buffer_index: int
    offset: int
    size: int
    shape: tuple


class FlatLayout:
    """Static packing of a pytree into dtype-homogeneous bucketed buffers."""

    def __init__(self, treedef, slots, buffer_sizes, buffer_dtypes):
        self.treedef = treedef
        self.slots = tuple(slots)                  # ordered by leaf_index
        self.buffer_sizes = tuple(buffer_sizes)
        self.buffer_dtypes = tuple(buffer_dtypes)  # the layout tree's dtypes
        self.num_buffers = len(buffer_sizes)
        self.num_leaves = len(self.slots)
        self.total_size = sum(buffer_sizes)

    @classmethod
    def from_tree(cls, tree, bucket_bytes: int = DEFAULT_BUCKET_BYTES):
        """Build from concrete arrays or ShapeDtypeStructs.  Leaves are
        packed first-seen-dtype-major, then greedily into buckets that close
        once they reach `bucket_bytes` (a single oversized leaf is its own
        bucket — leaves never straddle buckets)."""
        leaves, treedef = jax.tree.flatten(tree)
        by_dtype: dict = {}
        for i, leaf in enumerate(leaves):
            by_dtype.setdefault(jnp.dtype(leaf.dtype), []).append(i)

        slots = {}
        sizes, dtypes = [], []
        for dt, idxs in by_dtype.items():
            target = max(1, bucket_bytes // max(dt.itemsize, 1))
            cur_off = 0
            for i in idxs:
                size = math.prod(leaves[i].shape) if leaves[i].shape else 1
                if cur_off and cur_off + size > target:
                    sizes.append(cur_off)
                    dtypes.append(dt)
                    cur_off = 0
                if cur_off == 0:
                    buf_idx = len(sizes)
                slots[i] = Slot(i, buf_idx, cur_off, size,
                                tuple(leaves[i].shape))
                cur_off += size
            if cur_off:
                sizes.append(cur_off)
                dtypes.append(dt)
        ordered = [slots[i] for i in range(len(leaves))]
        return cls(treedef, ordered, sizes, dtypes)

    # ------------------------------------------------------------ pack ----

    def flatten(self, tree):
        """Pack a congruent tree into its buffers (list of 1-D arrays).

        Buffer dtype is taken from the tree being flattened, not the layout
        tree — e.g. f32 gradients of bf16 params pack into f32 buffers
        through the bf16 layout's slots.  All leaves landing in one bucket
        must agree on dtype."""
        leaves = jax.tree.leaves(tree)
        if len(leaves) != self.num_leaves:
            raise ValueError(
                f"tree has {len(leaves)} leaves, layout expects {self.num_leaves}")
        parts: list = [[] for _ in range(self.num_buffers)]
        for slot, leaf in zip(self.slots, leaves):
            if tuple(leaf.shape) != slot.shape:
                raise ValueError(
                    f"leaf {slot.leaf_index} shape {tuple(leaf.shape)} != "
                    f"layout shape {slot.shape}")
            parts[slot.buffer_index].append((slot.offset, leaf))
        buffers = []
        for bi, plist in enumerate(parts):
            plist.sort(key=lambda t: t[0])
            ravels = [jnp.ravel(leaf) for _, leaf in plist]
            if len({r.dtype for r in ravels}) != 1:
                raise ValueError(
                    f"buffer {bi} mixes dtypes {sorted({str(r.dtype) for r in ravels})}")
            buffers.append(ravels[0] if len(ravels) == 1
                           else jnp.concatenate(ravels))
        return buffers

    def unflatten(self, buffers):
        """Inverse of `flatten`: slice each leaf back out (bit-exact)."""
        if len(buffers) != self.num_buffers:
            raise ValueError(
                f"got {len(buffers)} buffers, layout expects {self.num_buffers}")
        for bi, (buf, size) in enumerate(zip(buffers, self.buffer_sizes)):
            if buf.size != size:
                raise ValueError(
                    f"buffer {bi} has {buf.size} elements, layout expects {size}")
        leaves = [
            buffers[s.buffer_index][s.offset:s.offset + s.size].reshape(s.shape)
            for s in self.slots]
        return self.treedef.unflatten(leaves)

    # --------------------------------------------------------- helpers ----

    def zeros(self, dtype=jnp.float32):
        """Fresh zero buffers (moment-state initialization)."""
        return [jnp.zeros((n,), dtype) for n in self.buffer_sizes]


def flatten_tree(tree, bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """One-shot convenience: (layout, buffers)."""
    layout = FlatLayout.from_tree(tree, bucket_bytes)
    return layout, layout.flatten(tree)


__all__ = ["FlatLayout", "Slot", "flatten_tree", "DEFAULT_BUCKET_BYTES"]
