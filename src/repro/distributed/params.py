"""Parameter / optimizer-state / cache PartitionSpec assignment.

Specs are derived from leaf *path names* (wq, w_gate, table, ...) with a
divisibility sanitizer: an axis assignment that does not evenly divide the
dimension is dropped (e.g. internvl2's 14 heads or whisper's 51865 vocab on a
16-wide model axis fall back to replication for that dim).  Leaves under the
scanned "blocks" subtree automatically get a leading None for the repeat axis.

Two layouts:
  * fsdp=False — paper-faithful FSDP-Norm: tensor dims over `model` only
    (params replicated across the data axes; the norm test owns those axes).
  * fsdp=True  — beyond-paper ACCUM-NORM: additionally shard a non-TP dim
    over the data axes (full-mesh ZeRO-3-style sharding).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P, NamedSharding

MODEL = "model"

# name -> preferred axes per dim (without leading scan axis).
# "F" marks the dim that takes the fsdp axes when fsdp=True.
_TABLE = {
    # embeddings
    "table": ("VOCAB_OR_F", None),
    # attention (d, H, hd) / (H, hd, d)
    "wq": ("F", MODEL, None),
    "wk": ("F", MODEL, None),
    "wv": ("F", MODEL, None),
    "wo": (MODEL, None, "F"),
    # MLA
    "w_dq": ("F", None),
    "w_uq": ("F", MODEL, None),
    "w_dkv": ("F", None),
    "w_krope": ("F", None),
    "w_uk": ("F", MODEL, None),
    "w_uv": ("F", MODEL, None),
    "w_o": (MODEL, None, "F"),
    # dense mlp
    "w_gate": ("F", MODEL),
    "w_up": ("F", MODEL),
    "w_down": (MODEL, "F"),
    # moe (router (d,E); experts (E,d,f)/(E,f,d))
    "router": ("F", None),
    # rglru
    "w_branch_a": ("F", MODEL),
    "w_branch_b": ("F", MODEL),
    "w_rg": ("F", MODEL),
    "w_ig": ("F", MODEL),
    "w_out": (MODEL, "F"),
    "conv_w": (None, MODEL),
    # ssd
    "w_in": ("F", MODEL),
}

# MoE expert tensors are 3-D with names shared with dense mlp; disambiguate by rank.
_MOE_TABLE = {
    "w_gate": (MODEL, "F", None),
    "w_up": (MODEL, "F", None),
    "w_down": (MODEL, "F", None),
}


def _sanitize(spec_axes, shape, mesh):
    out = []
    for dim, axes in zip(shape, spec_axes):
        if axes is None:
            out.append(None)
            continue
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        size = 1
        ok = True
        for a in axes_t:
            if a not in mesh.shape:
                ok = False
                break
            size *= mesh.shape[a]
        if ok and dim % size == 0 and size > 1:
            out.append(axes if len(axes_t) > 1 else axes_t[0])
        else:
            out.append(None)
    return P(*out)


def _leaf_spec(path_key: str, shape, mesh, fsdp_axes):
    name = path_key.split("/")[-1]
    in_scan = path_key.startswith("blocks/") or "/blocks/" in path_key
    ndim = len(shape) - (1 if in_scan else 0)

    axes = None
    if name in _MOE_TABLE and ndim == 3 and name in ("w_gate", "w_up", "w_down"):
        # expert tensors (E, d, f); dense mlp tensors are 2-D
        axes = _MOE_TABLE[name]
    elif name in _TABLE and len(_TABLE[name]) == ndim:
        axes = _TABLE[name]

    if axes is None:
        spec_axes = [None] * ndim
    else:
        spec_axes = []
        for a in axes:
            if a == "F":
                spec_axes.append(fsdp_axes if fsdp_axes else None)
            elif a == "VOCAB_OR_F":
                spec_axes.append(MODEL if not fsdp_axes else fsdp_axes)
            else:
                spec_axes.append(a)
    if in_scan:
        spec_axes = [None] + list(spec_axes)
    return _sanitize(spec_axes, shape, mesh)


def _path_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspecs(params, mesh, *, fsdp: bool = False):
    """PartitionSpec pytree matching `params`."""
    fsdp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data")) if fsdp else ()

    def leaf(path, x):
        return _leaf_spec(_path_key(path), x.shape, mesh, fsdp_axes)

    return jax.tree_util.tree_map_with_path(leaf, params)


def opt_pspecs(opt_state, param_specs):
    """Optimizer moments share the parameter layout; count is replicated."""
    return {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# ----------------------------------------------------------- cache specs ----

def cache_pspecs(cache, mesh, batch_divisible: bool):
    """Decode caches: batch over the data axes (when divisible), kv-heads /
    latent dims over `model` when divisible."""
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]

    def leaf(path, x):
        key = _path_key(path)
        name = key.split("/")[-1]
        in_scan = "scanned" in key
        ndim = len(x.shape) - (1 if in_scan else 0)
        batch_dim_size = x.shape[1] if in_scan else x.shape[0]
        baxes = daxes if (batch_divisible and batch_dim_size % dsize == 0) else None
        msize = mesh.shape.get(MODEL, 1)
        if name in ("k", "v") and ndim == 4:        # (b, s, kv, hd)
            # §Perf-3: prefer kv-head sharding when it divides the model
            # axis; otherwise sequence-shard LONG caches (the cache would
            # replicate 16x and dominate HBM — dbrx/llama/nemotron).  Short
            # ring caches stay replicated: the dus-on-sharded-dim overhead
            # outweighs sharding a few MB (phi3/gemma2 regression data in
            # EXPERIMENTS §Perf-3).
            kv_dim = x.shape[-2]
            s_dim = x.shape[-3]
            if kv_dim % msize == 0 and msize > 1:
                axes = [baxes, None, MODEL, None]
            elif s_dim >= 8192:
                axes = [baxes, MODEL, None, None]
            else:
                axes = [baxes, None, None, None]
        elif name == "c_kv" and ndim == 3:           # (b, s, r)
            axes = [baxes, MODEL if x.shape[-2] >= 8192 else None, None]
        elif name == "k_rope" and ndim == 3:
            axes = [baxes, MODEL if x.shape[-2] >= 8192 else None, None]
        elif name == "ssm" and ndim == 4:            # (b, nh, n, p)
            axes = [baxes, MODEL, None, None]
        elif name == "conv" and ndim == 3:           # (b, k, c)
            axes = [baxes, None, MODEL]
        elif name == "h" and ndim == 2:              # rglru state (b, w)
            axes = [baxes, MODEL]
        else:
            axes = [baxes] + [None] * (ndim - 1)
        if in_scan:
            axes = [None] + axes
        return _sanitize(axes, x.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, cache)
