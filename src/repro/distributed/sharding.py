"""Logical-axis sharding rules (MaxText-style) for all model code.

Model code annotates activations/params with *logical* axis names
("batch", "heads", "ffn", ...).  A `ShardingRules` mapping translates those to
physical mesh axes.  Outside a mesh context (CPU smoke tests) everything is a
no-op, so the same model code runs on 1 host device and on the 512-device
dry-run mesh unchanged.

Two execution modes share these rules:

* GSPMD mode (serving, ACCUM-NORM training): "batch" maps to the data axes.
* hybrid shard_map mode (FSDP-Norm training): the data axes are *manual*, so
  "batch" must map to None inside the manual region — `manual_data_rules`
  strips the data axes from the mapping while keeping "model"-axis rules
  active for GSPMD auto-partitioning.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import PartitionSpec as P


MeshAxes = tuple[str, ...] | str | None


@dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis names to physical mesh axes."""

    rules: dict[str, MeshAxes] = field(default_factory=dict)

    def spec(self, logical_axes: tuple[str | None, ...]) -> P:
        out = []
        for name in logical_axes:
            if name is None:
                out.append(None)
            else:
                out.append(self.rules.get(name))
        return P(*out)


# The production layout: tensor/expert/vocab dims over the `model` axis,
# batch over data axes; the fsdp axis for parameters is `model` (see DESIGN §2).
DEFAULT_RULES = ShardingRules(
    rules={
        "batch": ("data",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ffn": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "embed": None,          # d_model replicated (activations)
        "seq": None,
        "kv_seq": None,
        "act_seq": None,      # sequence parallelism (§Perf-1.5): off by default
        "lru_width": ("model",),
        "ssm_heads": ("model",),
        "state": None,
    }
)

MULTIPOD_RULES = ShardingRules(
    rules={**DEFAULT_RULES.rules, "batch": ("pod", "data")}
)

def with_sequence_parallel(rules: ShardingRules) -> ShardingRules:
    """Korthikanti-style sequence parallelism: residual-stream seq dim over
    the model axis between TP regions (norms/residuals compute on 1/16)."""
    return ShardingRules(rules={**rules.rules, "act_seq": ("model",)})

# Full-mesh FSDP layout for the beyond-paper ACCUM-NORM variant: parameters'
# large dims sharded over both axes.
FULL_FSDP_RULES = ShardingRules(
    rules={**DEFAULT_RULES.rules, "param_fsdp": ("data", "model")}
)


def manual_data_rules(rules: ShardingRules, manual_axes: tuple[str, ...]) -> ShardingRules:
    """Strip `manual_axes` from every rule (for use inside shard_map manual regions)."""
    new = {}
    for name, axes in rules.rules.items():
        if axes is None:
            new[name] = None
        elif isinstance(axes, str):
            new[name] = None if axes in manual_axes else axes
        else:
            kept = tuple(a for a in axes if a not in manual_axes)
            new[name] = kept if kept else None
    return ShardingRules(rules=new)


def flat_buffer_specs(num_buffers: int, axes: tuple[str, ...]) -> tuple[P, ...]:
    """Per-bucket `PartitionSpec`s for the DESIGN §9 flat buffers: every 1-D
    bucket shards its single dim over the data axes (the buckets are padded
    to an axes-product-divisible size by `FlatLayout.from_tree(...,
    shard_divisor=)`).  Empty `axes` (no data axis) degrades to replication."""
    spec = P(axes) if axes else P()
    return tuple(spec for _ in range(num_buffers))


def gather_flat_buffers(buffers, axes: tuple[str, ...]):
    """All-gather each 1/J bucket shard back into the full buffer inside a
    shard_map manual region (DESIGN §10 flat-resident params: the buffers
    REST as their `P(axes)` worker shard and the loss needs the whole
    parameter vector, so the FSDP param all-gather moves to the top of the
    step and operates on buffers).  Tiled gather along the single bucket
    dim, first data axis major — the same order `P(axes)` shards in."""
    if not axes:
        return list(buffers)
    return [jax.lax.all_gather(b, axes, tiled=True) for b in buffers]


def shard_flat_buffers(buffers, axes: tuple[str, ...]):
    """Constrain flat bucket buffers to their data-axis sharding (GSPMD
    steps; advisory outside a mesh context, like `maybe_shard`)."""
    if not axes:
        return buffers
    out = []
    for b in buffers:
        try:
            out.append(jax.lax.with_sharding_constraint(b, P(axes)))
        except ValueError:
            out.append(b)      # no mesh context (unit tests)
    return out


class _Ctx(threading.local):
    def __init__(self):
        self.rules: ShardingRules | None = None
        self.mesh: jax.sharding.Mesh | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_sharding_rules(rules: ShardingRules | None, mesh: jax.sharding.Mesh | None = None):
    prev_rules, prev_mesh = _CTX.rules, _CTX.mesh
    _CTX.rules, _CTX.mesh = rules, mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev_rules, prev_mesh


def current_rules() -> ShardingRules | None:
    return _CTX.rules


def logical_spec(*logical_axes: str | None) -> P:
    rules = _CTX.rules
    if rules is None:
        return P(*([None] * len(logical_axes)))
    return rules.spec(tuple(logical_axes))


def maybe_shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Apply a sharding constraint if rules are active; identity otherwise."""
    rules = _CTX.rules
    if rules is None:
        return x
    spec = rules.spec(tuple(logical_axes))
    if all(s is None for s in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except ValueError:
        # Outside of any mesh context (e.g. unit tests that set rules but no
        # mesh) — constraint is advisory, skip it.
        return x


__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "MULTIPOD_RULES",
    "FULL_FSDP_RULES",
    "manual_data_rules",
    "flat_buffer_specs",
    "gather_flat_buffers",
    "shard_flat_buffers",
    "use_sharding_rules",
    "current_rules",
    "logical_spec",
    "maybe_shard",
]
