"""Bucketed step-execution engine: recompile-free adaptive batch growth.

Algorithm 1 grows the global batch mid-training; under XLA every new
(M, micro_batch, seq) input shape retraces and recompiles the distributed
step — minutes of stall per increase at scale, defeating the efficiency
argument that motivates adaptive schedules.  This engine makes a
controller-driven batch increase a dictionary lookup (full design, padding
accounting, and cache-key scheme: DESIGN.md §8 "Bucketed step compilation"):

* a precomputed **ladder** of shape buckets (`core.schedule.bucket_ladder`,
  powers-of-two capacities consistent with `round_plan`);
* **quantization**: a requested `BatchPlan` maps to the smallest rung whose
  capacity covers it (never shrinking the request, clamped at `max_global`);
* **padding**: the real samples are laid into the rung's (M, B, seq) shape
  and the tail is filled with `labels = -1` slots, which the masked-mean,
  valid-token-weighted loss ignores exactly (`data.pipeline.pad_to_bucket`);
* a keyed **cache of compiled steps** — one trace per (rung, seq_len,
  extra-input) signature for the whole run;
* optional **ahead-of-time warmup** of the next-larger rung in a background
  thread, overlapped with training (XLA compilation releases the GIL), so
  the first step after an increase doesn't pay the compile either;
* optional **multi-host coordination** (DESIGN §8.1, `coordination.py`):
  rung-entry barriers so every host enters a new rung's executable together,
  leader-decided warmup agreement instead of per-host guessing, and a
  failure broadcast that downgrades the whole fleet to the synchronous-build
  fallback coherently when any host's warmup dies — plus the persistent
  compile cache so restarted / late-joining workers deserialize executables
  from disk instead of recompiling.

`EngineStats` (compile count, cache hits, padding-waste fraction, barrier
waits, desyncs, disk-cache hits) threads through `launch/train.py` history
into `benchmarks/run.py` rows so the recompile savings stay measurable.
"""

from __future__ import annotations

import contextlib
import threading
import time
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.core.schedule import BatchPlan, LadderShapeError, quantize_to_ladder
from repro.distributed.coordination import disk_cache_hits, enable_persistent_cache
from repro.testing.faults import fault_point


@dataclass
class EngineStats:
    """Counters proving the cache works (emitted into benchmark rows).

    `compiles`/`warmups` count COMPLETED builds only — a queued background
    warmup increments them when (and only when) its compile succeeds;
    failures land in `warmup_failures` and are re-raised by `drain()`."""
    compiles: int = 0          # distinct traces built (>= 1 per bucket used)
    hits: int = 0              # steps served from the cache
    warmups: int = 0           # buckets compiled ahead of time
    warmup_failures: int = 0   # background compiles that PERMANENTLY failed
    warmup_retries: int = 0    # transient warmup-compile attempts retried
    steps: int = 0
    real_samples: int = 0
    padded_samples: int = 0
    buckets_used: list = field(default_factory=list)
    # rung-transition accounting (DESIGN §14): a transition is a step whose
    # input signature differs from the previous step's; a transition HIT
    # found its executable already cached or pending from an AOT warmup
    # (a pending compile is still a hit — the step waits on the background
    # build instead of paying a fresh foreground trace).  Predictive warmup
    # targeting aims for transition_hits == transitions.
    transitions: int = 0
    transition_hits: int = 0
    # multi-host coordination (DESIGN §8.1; all zero without a coordinator)
    barriers: int = 0          # rung-entry barriers crossed
    barrier_wait_s: float = 0.0   # seconds THIS host waited for the fleet
    desyncs: int = 0           # local warmup proposal != fleet agreement
    coord_downgrades: int = 0  # queued warmups dropped on a remote failure
    # compiles served from the persistent disk cache — PROCESS-wide since
    # engine construction (the monitoring counter cannot attribute a hit to
    # a jit): sibling jits like train.py's eval fn count too, so read this
    # as "executables this job reused from disk", not an engine-only figure
    disk_cache_hits: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.steps if self.steps else 0.0

    @property
    def padding_waste(self) -> float:
        total = self.real_samples + self.padded_samples
        return self.padded_samples / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "compiles": self.compiles,
            "hits": self.hits,
            "warmups": self.warmups,
            "warmup_failures": self.warmup_failures,
            "warmup_retries": self.warmup_retries,
            "steps": self.steps,
            "hit_rate": round(self.hit_rate, 4),
            "padding_waste": round(self.padding_waste, 4),
            "buckets_used": list(self.buckets_used),
            "transitions": self.transitions,
            "transition_hits": self.transition_hits,
            "barriers": self.barriers,
            "barrier_wait_s": round(self.barrier_wait_s, 4),
            "desyncs": self.desyncs,
            "coord_downgrades": self.coord_downgrades,
            "disk_cache_hits": self.disk_cache_hits,
        }


def _batch_key(batch_like) -> tuple:
    """Cache key: the full input signature (names x shapes x dtypes), so any
    shape-relevant change — rung, seq_len, extra frontend inputs — is a new
    entry and everything else is a guaranteed hit."""
    return tuple(sorted(
        (k, tuple(v.shape), str(v.dtype)) for k, v in batch_like.items()))


def _sds(batch):
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}


def _key_tag(key: tuple) -> str:
    """Short, deterministic, filesystem-safe digest of a cache key — the
    vocabulary the coordinator speaks (barrier names, failure tags)."""
    return f"{zlib.crc32(repr(key).encode()) & 0xFFFFFFFF:08x}"


def _plan_tag(plan: BatchPlan | None) -> str:
    """Warmup-agreement payload: a rung identity, or 'none' at the ladder top."""
    return "none" if plan is None else f"{plan.micro_batch}x{plan.accum_steps}"


class RungCache:
    """The shared rung-cache/warmup core (DESIGN §8/§11).

    A keyed cache of compiled executables with (a) per-key build rendezvous —
    concurrent callers of the same key produce exactly ONE trace — and (b) a
    single-worker background AOT-warmup pool with exactly-once failure
    accounting.  Training's `BucketedEngine` and serving's
    `distributed.serve_engine.ServeEngine` both subclass it; a subclass
    supplies `_build` (foreground trace for a key's build argument) and
    `_aot_build` (background build + lower + compile).

    Thread safety: every `_cache`/`_pending`/`_building` access happens
    under `_lock`; the blocking waits (a pending warmup's `result()`, the
    actual trace) happen OUTSIDE it.

    Transient-failure policy (DESIGN §12): a background warmup compile that
    raises is retried up to `warmup_retries` times with exponential backoff
    (`warmup_backoff_s`, doubling) before it is treated as PERMANENT —
    only then does `_on_warmup_build_failure` fire (on the coordinated
    engine that hook broadcasts the failure fleet-wide, so a one-off OOM
    or filesystem blip no longer downgrades every host for the rest of the
    run).  Retry attempts are counted in `stats.warmup_retries`."""

    def __init__(self, *, mesh=None, aot: bool = False, stats=None,
                 warmup_retries: int = 2, warmup_backoff_s: float = 0.05):
        self._mesh = mesh
        self._aot = bool(aot)
        self._cache: dict[tuple, object] = {}     # ALL access under _lock
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(max_workers=1) if self._aot else None
        self._pending: dict[tuple, object] = {}   # key -> warmup Future
        self._building: dict[tuple, Future] = {}  # key -> foreground build
        self._warmup_errors: list[Exception] = []
        self._warmup_retries = max(0, int(warmup_retries))
        self._warmup_backoff_s = warmup_backoff_s
        self.stats = stats if stats is not None else EngineStats()

    # ------------------------------------------------------------- hooks --

    def _mesh_ctx(self):
        return (set_mesh(self._mesh) if self._mesh is not None
                else contextlib.nullcontext())

    def _build(self, build_arg):
        """Foreground trace for one key (subclass hook)."""
        raise NotImplementedError

    def _aot_build(self, build_arg):
        """Background build + AOT lower/compile for one key (subclass
        hook); only called when the cache was constructed with aot=True."""
        raise NotImplementedError

    def _on_warmup_build_failure(self, key: tuple):
        """Called from the warmup worker the moment its compile raises
        (before the failure is consumed); coordination hook, default no-op."""

    # ------------------------------------------------------------- cache --

    def lookup(self, key: tuple, build_arg):
        """The compiled executable for `key`; traces at most once per key
        across the run, even with concurrent callers.  A background warmup
        that failed is recorded (surfaced later by `drain()`) and the call
        falls back to a synchronous build.

        Every `_cache` read/write happens under `_lock` (a finishing AOT
        warmup and a foreground build used to race the unlocked check,
        double-compiling and double-counting `stats.compiles`).  Concurrent
        foreground callers rendezvous on a per-key `Future` in `_building`,
        so exactly one traces and the rest wait for it."""
        with self._lock:
            fut = self._pending.pop(key, None)
        if fut is not None:
            try:
                fn = fut.result()  # warmup finished or finishes now
            except Exception as e:               # noqa: BLE001 — surfaced in drain()
                self._record_warmup_failure(e, key)
            else:
                with self._lock:
                    self._cache.setdefault(key, fn)
        while True:
            with self._lock:
                fn = self._cache.get(key)
                if fn is not None:
                    self.stats.hits += 1
                    return fn
                bfut = self._building.get(key)
                if bfut is None:
                    bfut = self._building[key] = Future()
                    mine = True
                else:
                    mine = False
            if mine:
                try:
                    fault_point("engine.compile", key=key)
                    fn = self._build(build_arg)
                except BaseException as e:
                    with self._lock:
                        self._building.pop(key, None)
                    bfut.set_exception(e)
                    raise
                with self._lock:
                    self._cache[key] = fn
                    self._building.pop(key, None)
                    self.stats.compiles += 1
                bfut.set_result(fn)
                return fn
            # another foreground caller owns the build: wait, then re-check
            # the cache (on its failure, loop around and build ourselves).
            # Only the BUILDER's propagated failure is absorbed — an
            # interrupt raised in THIS thread while blocked must escape, or
            # Ctrl-C during a compile wait would silently retry forever.
            try:
                bfut.result()
            except Exception:                  # noqa: BLE001 — builder raised
                pass

    def cached(self, key: tuple) -> bool:
        """True when `key`'s executable is already resident (no build or
        warmup-wait would be paid to use it)."""
        with self._lock:
            return key in self._cache

    # ------------------------------------------------------- AOT warmup --

    def submit_warmup(self, key: tuple, build_arg) -> bool:
        """Queue a background AOT compile of `key`; no-op (False) when
        warmup is disabled or the key is already cached/pending.

        Stats accounting happens on COMPLETION inside the worker: a queued
        compile that later fails contributes to `warmup_failures`, never to
        `warmups`/`compiles`."""
        if not self._aot:
            return False
        with self._lock:
            if key in self._cache or key in self._pending:
                return False
            self._pending[key] = self._pool.submit(self._warm, build_arg, key)
        return True

    def _warm(self, build_arg, key):
        attempt = 0
        while True:
            try:
                fault_point("engine.warmup_compile", key=key, attempt=attempt)
                compiled = self._aot_build(build_arg)
                break
            except Exception:
                # transient until proven otherwise: bounded retry-with-
                # backoff BEFORE the permanent-failure hook (which, under
                # coordination, broadcasts the downgrade fleet-wide)
                if attempt >= self._warmup_retries:
                    self._on_warmup_build_failure(key)
                    raise
                attempt += 1
                with self._lock:
                    self.stats.warmup_retries += 1
                time.sleep(self._warmup_backoff_s * (2 ** (attempt - 1)))
            except BaseException:
                # interrupts/exits are never retried; the hook still fires
                # IMMEDIATELY (not when the failed future is eventually
                # consumed) — local stats stay consumption-time, exactly
                # once, in lookup/drain
                self._on_warmup_build_failure(key)
                raise
        with self._lock:     # success: count the finished warmup
            self.stats.warmups += 1
            self.stats.compiles += 1
        return compiled

    def _record_warmup_failure(self, exc: Exception, key: tuple | None = None):
        with self._lock:
            self.stats.warmup_failures += 1
            self._warmup_errors.append(exc)

    def drain(self, raise_errors: bool = True):
        """Block until queued warmups land in the cache (tests/teardown).

        Warmup exceptions — both ones recorded earlier by `lookup`'s
        fallback and ones surfacing now — are re-raised here (first one,
        with the failure count) instead of being swallowed into cache
        entries.  Pass raise_errors=False to only record them in
        `stats.warmup_failures` (the training loop does this: a failed
        warmup already fell back to a synchronous compile).

        Accounting is per-future exactly-once: a future is CLAIMED by
        atomically popping its key from `_pending` under the lock, and only
        the claimant records its outcome.  (`drain` used to iterate a stale
        snapshot of `_pending` while `get_step` popped and recorded the same
        future's failure — the one exception inflated `warmup_failures` to 2
        and a handled error was re-raised.)"""
        while True:
            with self._lock:
                if not self._pending:
                    break
                key = next(iter(self._pending))
                fut = self._pending.pop(key)
            try:
                fn = fut.result()
            except Exception as e:               # noqa: BLE001
                self._record_warmup_failure(e, key)
            else:
                with self._lock:   # cache writes stay under the lock
                    self._cache.setdefault(key, fn)
        with self._lock:
            errors, count = list(self._warmup_errors), self.stats.warmup_failures
            self._warmup_errors = []
        if errors and raise_errors:
            raise RuntimeError(
                f"{count} AOT warmup compile(s) failed; first error follows"
            ) from errors[0]


class BucketedEngine(RungCache):
    """Keyed cache of compiled train steps over a bucket ladder.

    wrap        : the step builder from `make_fsdp_norm_step` /
                  `make_accum_norm_step` (batch_like -> jitted step).
    ladder      : tuple[BatchPlan] from `core.schedule.bucket_ladder`.
    mesh        : bound while building/compiling (background threads must
                  re-enter it; mesh contexts are thread-local).
    params_like / opt_like : abstract step operands, only needed for
                  `aot_warmup` (lower+compile needs the full signature).
    coordinator : a `coordination.Coordinator` for multi-host runs (None =
                  uncoordinated, bit-identical to the single-host engine):
                  rung-entry barriers, warmup agreement, failure broadcast.
    persistent_cache_dir : when set, wires JAX's persistent compilation
                  cache (keyed per job/toolchain) so restarted or
                  late-joining workers deserialize executables from disk;
                  `stats.disk_cache_hits` counts the reuses.
    """

    def __init__(self, wrap, ladder: tuple[BatchPlan, ...], *, mesh=None,
                 params_like=None, opt_like=None, aot_warmup: bool = False,
                 coordinator=None, persistent_cache_dir: str | None = None,
                 warmup_retries: int = 2, warmup_backoff_s: float = 0.05):
        if not ladder:
            raise ValueError("bucket ladder must have at least one rung")
        super().__init__(mesh=mesh,
                         aot=aot_warmup and params_like is not None,
                         warmup_retries=warmup_retries,
                         warmup_backoff_s=warmup_backoff_s)
        self._wrap = wrap
        # the builder's shared per-step-signature FlatLayout (None on the
        # pure tree path): pinned at construction so every rung this engine
        # compiles provably reuses ONE layout (DESIGN §9/§10)
        self._flat_layout = getattr(wrap, "flat_layout", None)
        self.ladder = tuple(sorted(ladder, key=lambda p: p.global_batch))
        self._params_like = params_like
        self._opt_like = opt_like
        self._coord = coordinator
        self._last_key = None         # last step signature (transition stats)
        self._agree_seq = 0           # monotone warmup-agreement topic id
        self._agreed_for = None       # (bucket, proposal) the last agreement
        self._agreed_target = None    # ...and the rung the fleet settled on
        if persistent_cache_dir:
            enable_persistent_cache(persistent_cache_dir)
        # disk hits are a process-wide monitoring counter; this engine
        # reports the delta since its construction (an engine restart with a
        # warm cache directory therefore starts back at 0 and counts reuses)
        self._disk_base = disk_cache_hits()

    # ------------------------------------------------------ quantization --

    def bucket_for(self, desired_global: int,
                   max_global: int | None = None) -> BatchPlan:
        return quantize_to_ladder(desired_global, self.ladder, max_global)

    def next_bucket(self, bucket: BatchPlan) -> BatchPlan | None:
        """The next-larger rung (the AOT warmup target), or None at the top."""
        for plan in self.ladder:
            if plan.global_batch > bucket.global_batch:
                return plan
        return None

    # ------------------------------------------------------------- cache --

    def _build(self, batch_like):
        with self._mesh_ctx():
            fn = self._wrap(batch_like)
        lay = getattr(self._wrap, "flat_layout", None)
        if lay is not self._flat_layout:
            raise RuntimeError(
                "step builder changed its FlatLayout across bucket "
                "signatures — the per-step-signature layout must be built "
                "once and reused for every ladder rung (DESIGN §9/§10), or "
                "flat-resident params/moments from one rung would not feed "
                "the step compiled for the next")
        return fn

    def trace_step(self, batch_like):
        """Trace-only jaxpr of the step at `batch_like`'s signature — the
        `repro.analysis` entry point.  Never executes, never compiles, and
        never touches the cache or stats: the closed jaxpr of the FULL
        jitted step (pjit eqn included, so marker eqns, shardings, and
        donation flags are all visible to the static checker).  Off-ladder
        shapes raise `LadderShapeError` exactly as `get_step` would."""
        if self._params_like is None or self._opt_like is None:
            raise ValueError(
                "trace_step needs params_like/opt_like (the full abstract "
                "step signature) — construct the engine with both")
        self.check_on_ladder(batch_like)
        fn = self._build(_sds(batch_like))
        with self._mesh_ctx():
            return jax.make_jaxpr(fn)(
                self._params_like, self._opt_like, _sds(batch_like),
                jax.ShapeDtypeStruct((), jnp.float32))

    def lower_step(self, batch_like):
        """Lowered-HLO handle of the step at `batch_like`'s signature —
        the layer-3 cost-model entry point (DESIGN §15).  Lowers but never
        compiles, and like `trace_step` never touches the cache or stats;
        the returned `jax.stages.Lowered` exposes `.as_text()` (donation
        aliasing, shardings) and `.cost_analysis()` without ever loading
        an executable.  Off-ladder shapes raise `LadderShapeError`."""
        if self._params_like is None or self._opt_like is None:
            raise ValueError(
                "lower_step needs params_like/opt_like (the full abstract "
                "step signature) — construct the engine with both")
        self.check_on_ladder(batch_like)
        fn = self._build(_sds(batch_like))
        with self._mesh_ctx():
            return fn.lower(
                self._params_like, self._opt_like, _sds(batch_like),
                jax.ShapeDtypeStruct((), jnp.float32))

    def check_on_ladder(self, batch_like):
        """Reject a batch whose leading (M, B) dims match no ladder rung —
        BEFORE the cache is keyed or anything traces, so an off-ladder
        shape costs zero fresh lowerings instead of a silent one-off
        compile.  Leaves with fewer than two dims (scalars, per-step
        side inputs) carry no rung identity and are skipped."""
        rungs = sorted({(p.accum_steps, p.workers * p.micro_batch)
                        for p in self.ladder})
        for name in sorted(batch_like):
            v = batch_like[name]
            if len(getattr(v, "shape", ())) < 2:
                continue
            lead = tuple(v.shape[:2])
            if lead not in rungs:
                raise LadderShapeError(
                    f"batch leaf {name!r} has leading (M, B) dims {lead}, "
                    f"matching no ladder rung {rungs}; quantize the plan "
                    f"with bucket_for() and pad with pad_to_bucket() before "
                    f"stepping")

    def get_step(self, batch):
        """The compiled step for this (padded) batch's signature; traces at
        most once per signature across the run, even with concurrent
        callers (`RungCache.lookup`).  Off-ladder shapes are rejected up
        front with `LadderShapeError` (zero fresh lowerings).

        With a coordinator, stepping into a DIFFERENT signature than the
        last step is a rung transition: remote warmup failures are polled
        (a rung any host flagged gets its queued-not-started warmup dropped
        — the coherent downgrade to the synchronous path) and the rung-entry
        barrier holds this host until the whole fleet is ready to enter the
        new executable together."""
        self.check_on_ladder(batch)
        key = _batch_key(batch)
        if key != self._last_key:
            if self._last_key is not None:
                # a rung transition: count whether AOT warmup covered it
                # (cached, or pending — waiting on a background compile is
                # the warmed path, not a fresh foreground trace)
                with self._lock:
                    self.stats.transitions += 1
                    if key in self._cache or key in self._pending:
                        self.stats.transition_hits += 1
            if self._coord is not None:
                self._enter_rung(key)
            self._last_key = key
        return self.lookup(key, _sds(batch))

    def _enter_rung(self, key: tuple):
        """Multi-host rung transition (DESIGN §8.1): coherent-downgrade check
        + entry barrier.  Called once per change of step signature."""
        tag = _key_tag(key)
        if tag in self._coord.poll_failures():
            # some host's warmup of THIS rung died: nobody may depend on a
            # background compile landing.  A queued-not-started warmup is
            # cancelled (foreground build instead); one already running is
            # left in place — blocking on an in-flight compile IS the
            # synchronous fallback, and cancelling it could not stop it.
            with self._lock:
                fut = self._pending.get(key)
                if fut is not None and fut.cancel():
                    self._pending.pop(key, None)
                    self.stats.coord_downgrades += 1
        wait = self._coord.barrier(f"rung-{tag}")
        with self._lock:
            self.stats.barriers += 1
            self.stats.barrier_wait_s += wait

    def _record_warmup_failure(self, exc: Exception, key: tuple | None = None):
        super()._record_warmup_failure(exc, key)
        if self._coord is not None and key is not None:
            # fleet-wide coherence: every other host downgrades this rung to
            # the synchronous-build fallback instead of waiting on a warmup
            self._coord.broadcast_failure(_key_tag(key))

    def observe(self, plan: BatchPlan, bucket: BatchPlan):
        """Record one executed step's padding accounting."""
        self.stats.steps += 1
        self.stats.real_samples += plan.global_batch
        self.stats.padded_samples += bucket.global_batch - plan.global_batch
        tag = f"{bucket.micro_batch}x{bucket.accum_steps}"
        if tag not in self.stats.buckets_used:
            self.stats.buckets_used.append(tag)
        self._refresh_disk_hits()

    def _refresh_disk_hits(self):
        """Fold the process-wide persistent-cache hit counter into stats.

        Foreground compiles are lazy (XLA builds at the step's first CALL,
        after `get_step` returned), so the delta is refreshed at the two
        points that straddle them: each `observe` and `drain`."""
        hits = disk_cache_hits() - self._disk_base
        if hits > self.stats.disk_cache_hits:
            self.stats.disk_cache_hits = hits

    # ------------------------------------------------------- AOT warmup --

    def warmup(self, bucket: BatchPlan, batch_example: dict):
        """Queue an ahead-of-time compile of `bucket` shaped like
        `batch_example` (tail dims reused; leading dims replaced by the
        rung's (M, B)).  No-op unless aot_warmup was enabled.

        Stats accounting happens on COMPLETION inside the worker: a queued
        compile that later fails contributes to `warmup_failures`, never to
        `warmups`/`compiles`."""
        if not self._aot or bucket is None:
            return
        batch_like = {
            k: jax.ShapeDtypeStruct(
                (bucket.accum_steps, bucket.workers * bucket.micro_batch)
                + tuple(v.shape[2:]), v.dtype)
            for k, v in batch_example.items()}
        self.submit_warmup(_batch_key(batch_like), batch_like)

    def warmup_agreed(self, bucket: BatchPlan, batch_example: dict,
                      proposal: BatchPlan | None = None):
        """Coordinated AOT warmup: the fleet agrees on ONE rung to
        background-compile instead of each host guessing (DESIGN §8.1).

        `proposal` is the rung to warm — the caller's predicted target rung
        (DESIGN §14) or, when None, the next-larger rung (the pre-predictor
        behavior).  Every host submits its proposal; the leader's wins.  A
        host whose proposal differs (controller state drifted, restart
        mid-ladder) counts a `desync` and warms the agreed rung anyway, so
        the eventual rung transition is a cache hit everywhere.  Returns
        the rung actually queued (None at the ladder top).

        One agreement per (bucket, proposal) CHANGE, not per step:
        re-agreeing every step would add a per-step fleet rendezvous (and,
        on the file coordinator, a file per step) to the hot loop for an
        answer that cannot change.  Topic ids are a per-engine monotone
        counter, and both the bucket sequence and the caller's proposal are
        pure functions of globally-reduced controller state, so hosts
        trigger re-agreement at the same steps and consume the same topic
        stream; a host whose local state drifted still converges on the
        leader's answer via the desync path.

        Uncoordinated (or world-of-one) engines skip the agreement and
        behave exactly like `warmup(proposal or next_bucket(bucket), ...)`."""
        if proposal is None:
            proposal = self.next_bucket(bucket)
        if (not self._aot or self._coord is None
                or getattr(self._coord, "world", 1) == 1):
            self.warmup(proposal, batch_example)
            return proposal
        cur = (_plan_tag(bucket), _plan_tag(proposal))
        if cur != self._agreed_for:
            self._agree_seq += 1
            prop_tag = _plan_tag(proposal)
            agreed = self._coord.agree(f"warmup-{self._agree_seq}", prop_tag)
            target = proposal
            if agreed != prop_tag:
                with self._lock:
                    self.stats.desyncs += 1
                target = next(
                    (p for p in self.ladder if _plan_tag(p) == agreed), None)
            self._agreed_for, self._agreed_target = cur, target
        if self._agreed_target is not None:
            self.warmup(self._agreed_target, batch_example)
        return self._agreed_target

    def _aot_build(self, batch_like):
        fn = self._build(batch_like)
        with self._mesh_ctx():
            return fn.lower(
                self._params_like, self._opt_like, batch_like,
                jax.ShapeDtypeStruct((), jnp.float32)).compile()

    def _on_warmup_build_failure(self, key: tuple):
        # broadcast IMMEDIATELY (not when this host eventually consumes
        # the failed future): hosts polling at rung entry downgrade to
        # the synchronous build instead of counting on a warmup that
        # already died.  Local stats stay consumption-time — exactly
        # once, in get_step/drain — and the broadcast is idempotent.
        if self._coord is not None:
            self._coord.broadcast_failure(_key_tag(key))

    def drain(self, raise_errors: bool = True):
        try:
            super().drain(raise_errors)
        finally:
            self._refresh_disk_hits()


__all__ = ["BucketedEngine", "EngineStats", "LadderShapeError", "RungCache"]
