"""Bucketed step-execution engine: recompile-free adaptive batch growth.

Algorithm 1 grows the global batch mid-training; under XLA every new
(M, micro_batch, seq) input shape retraces and recompiles the distributed
step — minutes of stall per increase at scale, defeating the efficiency
argument that motivates adaptive schedules.  This engine makes a
controller-driven batch increase a dictionary lookup (full design, padding
accounting, and cache-key scheme: DESIGN.md §8 "Bucketed step compilation"):

* a precomputed **ladder** of shape buckets (`core.schedule.bucket_ladder`,
  powers-of-two capacities consistent with `round_plan`);
* **quantization**: a requested `BatchPlan` maps to the smallest rung whose
  capacity covers it (never shrinking the request, clamped at `max_global`);
* **padding**: the real samples are laid into the rung's (M, B, seq) shape
  and the tail is filled with `labels = -1` slots, which the masked-mean,
  valid-token-weighted loss ignores exactly (`data.pipeline.pad_to_bucket`);
* a keyed **cache of compiled steps** — one trace per (rung, seq_len,
  extra-input) signature for the whole run;
* optional **ahead-of-time warmup** of the next-larger rung in a background
  thread, overlapped with training (XLA compilation releases the GIL), so
  the first step after an increase doesn't pay the compile either.

`EngineStats` (compile count, cache hits, padding-waste fraction) threads
through `launch/train.py` history into `benchmarks/run.py` rows so the
recompile savings stay measurable.
"""

from __future__ import annotations

import contextlib
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.core.schedule import BatchPlan, quantize_to_ladder


@dataclass
class EngineStats:
    """Counters proving the cache works (emitted into benchmark rows).

    `compiles`/`warmups` count COMPLETED builds only — a queued background
    warmup increments them when (and only when) its compile succeeds;
    failures land in `warmup_failures` and are re-raised by `drain()`."""
    compiles: int = 0          # distinct traces built (>= 1 per bucket used)
    hits: int = 0              # steps served from the cache
    warmups: int = 0           # buckets compiled ahead of time
    warmup_failures: int = 0   # background compiles that raised
    steps: int = 0
    real_samples: int = 0
    padded_samples: int = 0
    buckets_used: list = field(default_factory=list)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.steps if self.steps else 0.0

    @property
    def padding_waste(self) -> float:
        total = self.real_samples + self.padded_samples
        return self.padded_samples / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "compiles": self.compiles,
            "hits": self.hits,
            "warmups": self.warmups,
            "warmup_failures": self.warmup_failures,
            "steps": self.steps,
            "hit_rate": round(self.hit_rate, 4),
            "padding_waste": round(self.padding_waste, 4),
            "buckets_used": list(self.buckets_used),
        }


def _batch_key(batch_like) -> tuple:
    """Cache key: the full input signature (names x shapes x dtypes), so any
    shape-relevant change — rung, seq_len, extra frontend inputs — is a new
    entry and everything else is a guaranteed hit."""
    return tuple(sorted(
        (k, tuple(v.shape), str(v.dtype)) for k, v in batch_like.items()))


def _sds(batch):
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}


class BucketedEngine:
    """Keyed cache of compiled train steps over a bucket ladder.

    wrap        : the step builder from `make_fsdp_norm_step` /
                  `make_accum_norm_step` (batch_like -> jitted step).
    ladder      : tuple[BatchPlan] from `core.schedule.bucket_ladder`.
    mesh        : bound while building/compiling (background threads must
                  re-enter it; mesh contexts are thread-local).
    params_like / opt_like : abstract step operands, only needed for
                  `aot_warmup` (lower+compile needs the full signature).
    """

    def __init__(self, wrap, ladder: tuple[BatchPlan, ...], *, mesh=None,
                 params_like=None, opt_like=None, aot_warmup: bool = False):
        if not ladder:
            raise ValueError("bucket ladder must have at least one rung")
        self._wrap = wrap
        # the builder's shared per-step-signature FlatLayout (None on the
        # pure tree path): pinned at construction so every rung this engine
        # compiles provably reuses ONE layout (DESIGN §9/§10)
        self._flat_layout = getattr(wrap, "flat_layout", None)
        self.ladder = tuple(sorted(ladder, key=lambda p: p.global_batch))
        self._mesh = mesh
        self._params_like = params_like
        self._opt_like = opt_like
        self._aot = aot_warmup and params_like is not None
        self._cache: dict[tuple, object] = {}     # ALL access under _lock
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(max_workers=1) if self._aot else None
        self._pending: dict[tuple, object] = {}   # key -> warmup Future
        self._building: dict[tuple, Future] = {}  # key -> foreground build
        self._warmup_errors: list[Exception] = []
        self.stats = EngineStats()

    # ------------------------------------------------------ quantization --

    def bucket_for(self, desired_global: int,
                   max_global: int | None = None) -> BatchPlan:
        return quantize_to_ladder(desired_global, self.ladder, max_global)

    def next_bucket(self, bucket: BatchPlan) -> BatchPlan | None:
        """The next-larger rung (the AOT warmup target), or None at the top."""
        for plan in self.ladder:
            if plan.global_batch > bucket.global_batch:
                return plan
        return None

    # ------------------------------------------------------------- cache --

    def _mesh_ctx(self):
        return (set_mesh(self._mesh) if self._mesh is not None
                else contextlib.nullcontext())

    def _build(self, batch_like):
        with self._mesh_ctx():
            fn = self._wrap(batch_like)
        lay = getattr(self._wrap, "flat_layout", None)
        if lay is not self._flat_layout:
            raise RuntimeError(
                "step builder changed its FlatLayout across bucket "
                "signatures — the per-step-signature layout must be built "
                "once and reused for every ladder rung (DESIGN §9/§10), or "
                "flat-resident params/moments from one rung would not feed "
                "the step compiled for the next")
        return fn

    def get_step(self, batch):
        """The compiled step for this (padded) batch's signature; traces at
        most once per signature across the run, even with concurrent
        callers.  A background warmup that failed is recorded (surfaced
        later by `drain()`) and the step falls back to a synchronous build.

        Thread safety: every `_cache` read/write happens under `_lock`
        (a finishing AOT warmup and a foreground build used to race the
        unlocked check, double-compiling and double-counting
        `stats.compiles`).  The blocking waits — a pending warmup's
        `result()` and the actual trace — happen OUTSIDE the lock;
        concurrent foreground callers rendezvous on a per-key `Future` in
        `_building`, so exactly one traces and the rest wait for it."""
        key = _batch_key(batch)
        with self._lock:
            fut = self._pending.pop(key, None)
        if fut is not None:
            try:
                fn = fut.result()  # warmup finished or finishes now
            except Exception as e:               # noqa: BLE001 — surfaced in drain()
                self._record_warmup_failure(e)
            else:
                with self._lock:
                    self._cache.setdefault(key, fn)
        while True:
            with self._lock:
                fn = self._cache.get(key)
                if fn is not None:
                    self.stats.hits += 1
                    return fn
                bfut = self._building.get(key)
                if bfut is None:
                    bfut = self._building[key] = Future()
                    mine = True
                else:
                    mine = False
            if mine:
                try:
                    fn = self._build(_sds(batch))
                except BaseException as e:
                    with self._lock:
                        self._building.pop(key, None)
                    bfut.set_exception(e)
                    raise
                with self._lock:
                    self._cache[key] = fn
                    self._building.pop(key, None)
                    self.stats.compiles += 1
                bfut.set_result(fn)
                return fn
            # another foreground caller owns the build: wait, then re-check
            # the cache (on its failure, loop around and build ourselves).
            # Only the BUILDER's propagated failure is absorbed — an
            # interrupt raised in THIS thread while blocked must escape, or
            # Ctrl-C during a compile wait would silently retry forever.
            try:
                bfut.result()
            except Exception:                  # noqa: BLE001 — builder raised
                pass

    def _record_warmup_failure(self, exc: Exception):
        with self._lock:
            self.stats.warmup_failures += 1
            self._warmup_errors.append(exc)

    def observe(self, plan: BatchPlan, bucket: BatchPlan):
        """Record one executed step's padding accounting."""
        self.stats.steps += 1
        self.stats.real_samples += plan.global_batch
        self.stats.padded_samples += bucket.global_batch - plan.global_batch
        tag = f"{bucket.micro_batch}x{bucket.accum_steps}"
        if tag not in self.stats.buckets_used:
            self.stats.buckets_used.append(tag)

    # ------------------------------------------------------- AOT warmup --

    def warmup(self, bucket: BatchPlan, batch_example: dict):
        """Queue an ahead-of-time compile of `bucket` shaped like
        `batch_example` (tail dims reused; leading dims replaced by the
        rung's (M, B)).  No-op unless aot_warmup was enabled.

        Stats accounting happens on COMPLETION inside the worker: a queued
        compile that later fails contributes to `warmup_failures`, never to
        `warmups`/`compiles`."""
        if not self._aot or bucket is None:
            return
        batch_like = {
            k: jax.ShapeDtypeStruct(
                (bucket.accum_steps, bucket.workers * bucket.micro_batch)
                + tuple(v.shape[2:]), v.dtype)
            for k, v in batch_example.items()}
        key = _batch_key(batch_like)
        with self._lock:
            if key in self._cache or key in self._pending:
                return
            self._pending[key] = self._pool.submit(
                self._compile_aot, batch_like)

    def _compile_aot(self, batch_like):
        fn = self._build(batch_like)
        with self._mesh_ctx():
            compiled = fn.lower(
                self._params_like, self._opt_like, batch_like,
                jax.ShapeDtypeStruct((), jnp.float32)).compile()
        with self._lock:     # success: count the finished warmup
            self.stats.warmups += 1
            self.stats.compiles += 1
        return compiled

    def drain(self, raise_errors: bool = True):
        """Block until queued warmups land in the cache (tests/teardown).

        Warmup exceptions — both ones recorded earlier by `get_step`'s
        fallback and ones surfacing now — are re-raised here (first one,
        with the failure count) instead of being swallowed into cache
        entries.  Pass raise_errors=False to only record them in
        `stats.warmup_failures` (the training loop does this: a failed
        warmup already fell back to a synchronous compile)."""
        with self._lock:
            pending = list(self._pending.items())
        for key, fut in pending:
            try:
                fn = fut.result()
            except Exception as e:               # noqa: BLE001
                self._record_warmup_failure(e)
            else:
                with self._lock:   # cache writes stay under the lock
                    self._cache.setdefault(key, fn)
            with self._lock:
                self._pending.pop(key, None)
        with self._lock:
            errors, count = list(self._warmup_errors), self.stats.warmup_failures
            self._warmup_errors = []
        if errors and raise_errors:
            raise RuntimeError(
                f"{count} AOT warmup compile(s) failed; first error follows"
            ) from errors[0]


__all__ = ["BucketedEngine", "EngineStats"]
