"""Adaptive continuous-batching serve engine (DESIGN §11).

The serving mirror of the training stack's recompile-free adaptive batching:
where `BucketedEngine` quantizes the CONTROLLER's batch plan onto a shape
ladder of precompiled train steps, `ServeEngine` quantizes the IN-FLIGHT
request batch onto a powers-of-two rung ladder of precompiled decode steps
(`serve_step.make_slot_decode_step`), shares the same `RungCache`
concurrency core (per-key build rendezvous, background AOT warmup with
exactly-once failure accounting), and adapts the active rung to measured
load via `core.serve_controller` the way training adapts to gradient noise.

Residency (the FlatLayout lesson applied to KV): ONE cache buffer is
allocated at the top rung and never reallocated.  Requests own slot rows;
admission zeroes a row, completion backfills the freed row from the highest
active slot (`move_slot` — one compiled executable serves every (src, dst)
pair), and a rung change re-slices the same buffer — zero cache bytes
move, zero recompiles once the rung is warm.

Continuous batching at token granularity: every in-flight request lives on
its own timeline (per-slot position vectors, `models.attention`/`mla`
vector-pos decode).  A newly admitted request streams its prompt through
the SAME rung decode step (teacher-forced), then flips to generation — so
prefill and decode share one executable per rung and requests join/leave
the batch at any step.  Production prefill for long prompts would add a
chunked full-sequence prefill executable per (rung, prompt-bucket); at this
repo's smoke scale the streamed path keeps the executable count at one per
rung (noted in DESIGN §11).

Greedy decoding only (argmax inside the compiled step — one (b,) int32
transfer per step, not a (b, vocab) logits readback).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.serve_controller import (
    ServeControllerConfig, init_serve_controller, observe_step_latency,
    serve_controller_update, serve_ladder)
from repro.distributed.engine import EngineStats, RungCache
from repro.distributed.serve_step import (
    make_slot_decode_step, move_slot, reset_slot)


class QueueFullError(RuntimeError):
    """Admission control: the engine's wait queue is at `max_queue` and this
    request was REJECTED (never enqueued).  Callers load-shed — retry later
    or route elsewhere; unbounded queues just convert overload into
    unbounded latency."""

    def __init__(self, message: str, *, queued: int = 0, max_queue: int = 0):
        super().__init__(message)
        self.queued = queued
        self.max_queue = max_queue


@dataclass
class ServeStats(EngineStats):
    """Engine counters plus serving-tier accounting.  `steps` counts engine
    decode iterations; `real_samples`/`padded_samples` reuse the training
    meaning (occupied vs empty slot-rows per step), so `padding_waste` is
    the fraction of decode rows burned on empty slots."""
    requests_submitted: int = 0
    requests_completed: int = 0
    requests_rejected: int = 0    # load-shed at submit (queue at max_queue)
    tokens_generated: int = 0     # generated (post-prompt) tokens only
    prompt_tokens: int = 0        # prompt tokens streamed through decode
    rung_transitions: int = 0     # steps whose rung differs from the last
    transition_hits: int = 0      # ...that found the executable already warm
    slot_resets: int = 0          # admissions (each zeroes one slot row)
    slot_moves: int = 0           # compaction copies after completions

    def as_dict(self) -> dict:
        d = super().as_dict()
        d.update({
            "requests_submitted": self.requests_submitted,
            "requests_completed": self.requests_completed,
            "requests_rejected": self.requests_rejected,
            "tokens_generated": self.tokens_generated,
            "prompt_tokens": self.prompt_tokens,
            "rung_transitions": self.rung_transitions,
            "transition_hits": self.transition_hits,
            "slot_resets": self.slot_resets,
            "slot_moves": self.slot_moves,
        })
        return d


@dataclass
class Request:
    """One in-flight generation request (host-side bookkeeping)."""
    rid: int
    prompt: np.ndarray                # (prompt_len,) int32
    max_new_tokens: int
    arrival_s: float
    generated: list = field(default_factory=list)
    pos: int = 0                      # next cache position its slot writes
    n_consumed: int = 0               # prompt tokens streamed so far
    first_token_s: float | None = None
    done_s: float | None = None

    @property
    def prefilling(self) -> bool:
        return self.n_consumed < len(self.prompt)

    @property
    def latency_s(self) -> float | None:
        return None if self.done_s is None else self.done_s - self.arrival_s


class ServeEngine(RungCache):
    """Ladder-bucketed continuous-batching engine over one resident KV pool.

    model / params : the served model (decoder-style `decode_step` API).
    mesh           : decode-step sharding mesh (params replicated over data
                     axes, cache slot-sharded when max_slots divides J).
    max_slots      : top rung — the resident cache's slot-row count.
    cache_len      : per-slot cache length; every request must satisfy
                     prompt_len + max_new_tokens <= cache_len.
    ladder         : ascending request-batch rungs (default: powers of two
                     up to max_slots).
    controller     : `ServeControllerConfig` (default: ladder + eager grow,
                     patience-4 shrink, no latency SLO).
    aot_warmup     : background-compile rungs adjacent to the active one so
                     a controller rung change is a cache hit, not a stall.
    """

    def __init__(self, model, params, mesh, *, max_slots: int, cache_len: int,
                 ladder: tuple[int, ...] | None = None,
                 controller: ServeControllerConfig | None = None,
                 aot_warmup: bool = False, ring: bool = False,
                 max_queue: int = 0):
        if ring:
            raise NotImplementedError(
                "ring-buffer slot caches need per-slot wrap accounting")
        super().__init__(mesh=mesh, aot=aot_warmup, stats=ServeStats())
        self.ladder = tuple(sorted(set(ladder))) if ladder else \
            serve_ladder(max_slots)
        if self.ladder[-1] > max_slots:
            raise ValueError(
                f"ladder top {self.ladder[-1]} exceeds max_slots {max_slots}")
        self.max_slots = max_slots
        self.cache_len = cache_len
        self._model = model
        self._params = params
        self._wrap, self._p_specs, cache_specs = make_slot_decode_step(
            model, mesh, max_slots=max_slots,
            params_like=jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))

        kv = model.init_cache(max_slots, cache_len)
        self._kv_like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), kv)
        self._c_specs = cache_specs(self._kv_like)
        with self._mesh_ctx():
            self._kv = jax.device_put(kv, jax.tree.map(
                lambda s: NamedSharding(mesh, s), self._c_specs,
                is_leaf=lambda s: isinstance(s, P)))
        # slot maintenance executables: ONE compile each for the whole run
        # (src/dst/slot are traced scalars), resident buffer donated through
        self._move = jax.jit(move_slot, donate_argnums=(0,))
        self._reset = jax.jit(reset_slot, donate_argnums=(0,))

        self._ctrl_cfg = controller or ServeControllerConfig(ladder=self.ladder)
        if self._ctrl_cfg.ladder != self.ladder:
            raise ValueError("controller ladder must match engine ladder")
        if max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.max_queue = max_queue            # 0 = unbounded (the default)
        self.ctrl = init_serve_controller(self._ctrl_cfg)
        self.queue: deque[Request] = deque()
        self._active: list[Request] = []      # index == slot row
        self._last_rung: int | None = None
        self._next_rid = 0

    # --------------------------------------------------------- admission --

    @property
    def num_active(self) -> int:
        return len(self._active)

    @property
    def current_rung(self) -> int:
        return self.ladder[self.ctrl.rung]

    def submit(self, prompt, max_new_tokens: int,
               arrival_s: float | None = None) -> Request:
        """Enqueue one request; decode work happens in `step()`.

        Raises `QueueFullError` (and counts `requests_rejected`) when the
        wait queue already holds `max_queue` requests — malformed requests
        (empty prompt, cache overrun) stay ValueError and count as neither
        submitted nor rejected."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if len(prompt) + max_new_tokens > self.cache_len:
            raise ValueError(
                f"prompt_len {len(prompt)} + max_new_tokens {max_new_tokens} "
                f"exceeds cache_len {self.cache_len}")
        if self.max_queue and len(self.queue) >= self.max_queue:
            self.stats.requests_rejected += 1
            raise QueueFullError(
                f"serve queue full: {len(self.queue)} queued >= max_queue "
                f"{self.max_queue} (request rejected, not enqueued)",
                queued=len(self.queue), max_queue=self.max_queue)
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      arrival_s=time.time() if arrival_s is None else arrival_s)
        self._next_rid += 1
        self.queue.append(req)
        self.stats.requests_submitted += 1
        return req

    def _admit(self, req: Request):
        slot = len(self._active)
        self._kv = self._reset(self._kv, jnp.int32(slot))
        self.stats.slot_resets += 1
        req.pos = 0
        req.n_consumed = 0
        self._active.append(req)

    # -------------------------------------------------------- decode step --

    def _rung_key(self, b: int) -> tuple:
        return ("decode", b, self.cache_len)

    def _build(self, b: int):
        with self._mesh_ctx():
            return self._wrap(b, self._kv_like)

    def _aot_build(self, b: int):
        with self._mesh_ctx():
            fn = self._wrap(b, self._kv_like)
            tok = jax.ShapeDtypeStruct((b,), jnp.int32)
            return fn.lower(self._params_sds(), self._kv_like, tok, tok
                            ).compile()

    def _params_sds(self):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self._params)

    def warm(self, rungs) -> None:
        """Queue background AOT compiles for the given rung batch sizes."""
        for b in rungs:
            if b in self.ladder:
                self.submit_warmup(self._rung_key(b), b)

    def _warm_adjacent(self, rung_idx: int):
        """The serve analog of train's next-rung warmup: the controller
        moves one rung at a time, so compile BOTH neighbors ahead of it."""
        for j in (rung_idx + 1, rung_idx - 1):
            if 0 <= j < len(self.ladder):
                self.submit_warmup(self._rung_key(self.ladder[j]),
                                   self.ladder[j])

    def step(self) -> dict | None:
        """One engine iteration: controller decision, admissions, one
        compiled decode step at the active rung, host-side advance +
        completions.  Returns a step report, or None when idle."""
        if not self._active and not self.queue:
            return None
        self.ctrl = serve_controller_update(
            self._ctrl_cfg, self.ctrl, queued=len(self.queue),
            active=len(self._active))
        rung_idx = self.ctrl.rung
        b = self.ladder[rung_idx]
        while self.queue and len(self._active) < b:
            self._admit(self.queue.popleft())

        key = self._rung_key(b)
        if b != self._last_rung:
            if self._last_rung is not None:
                self.stats.rung_transitions += 1
                if self.cached(key):
                    self.stats.transition_hits += 1
            self._last_rung = b
        fn = self.lookup(key, b)

        tokens = np.zeros((b,), np.int32)
        pos = np.zeros((b,), np.int32)
        for s, r in enumerate(self._active):
            tokens[s] = (r.prompt[r.n_consumed] if r.prefilling
                         else r.generated[-1])
            pos[s] = r.pos
        t0 = time.time()
        with self._mesh_ctx():
            out_tok, self._kv = fn(self._params, self._kv,
                                   jnp.asarray(tokens), jnp.asarray(pos))
        out = np.asarray(out_tok)            # blocks on the device step
        dt = time.time() - t0
        self.ctrl = observe_step_latency(self._ctrl_cfg, self.ctrl,
                                         rung_idx, dt)
        if self._aot:
            self._warm_adjacent(rung_idx)

        completed = self._advance(out)
        self.stats.steps += 1
        self.stats.real_samples += len(self._active) + len(completed)
        self.stats.padded_samples += b - len(self._active) - len(completed)
        tag = str(b)
        if tag not in self.stats.buckets_used:
            self.stats.buckets_used.append(tag)
        return {"rung": b, "active": len(self._active),
                "queued": len(self.queue), "step_s": dt,
                "completed": completed}

    def _advance(self, out: np.ndarray) -> list[Request]:
        """Fold one step's sampled tokens into per-request state; retire
        finished requests and compact their slots (highest active slot
        backfills the freed row — its cache row moves, nothing else)."""
        now = time.time()
        done_slots = []
        for s, r in enumerate(self._active):
            if r.prefilling:
                r.n_consumed += 1
                self.stats.prompt_tokens += 1
                if not r.prefilling:     # last prompt token -> first output
                    r.generated.append(int(out[s]))
                    r.first_token_s = now
                    self.stats.tokens_generated += 1
            else:
                r.generated.append(int(out[s]))
                self.stats.tokens_generated += 1
            r.pos += 1
            if (len(r.generated) >= r.max_new_tokens
                    or r.pos >= self.cache_len):
                r.done_s = now
                done_slots.append(s)
        completed = [self._active[s] for s in done_slots]
        for s in sorted(done_slots, reverse=True):
            last = len(self._active) - 1
            if s != last:
                self._kv = self._move(self._kv, jnp.int32(last),
                                      jnp.int32(s))
                self._active[s] = self._active[last]
                self.stats.slot_moves += 1
            self._active.pop()
        self.stats.requests_completed += len(completed)
        return completed

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        """Step until queue and in-flight batch are empty; returns every
        request completed along the way."""
        done: list[Request] = []
        for _ in range(max_steps):
            report = self.step()
            if report is None:
                return done
            done.extend(report["completed"])
        raise RuntimeError(f"not drained after {max_steps} steps "
                           f"(active={len(self._active)}, "
                           f"queued={len(self.queue)})")


__all__ = ["QueueFullError", "Request", "ServeEngine", "ServeStats"]
