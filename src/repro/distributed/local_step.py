"""Communication-efficient local-update training with adaptive batch sizes —
the paper's companion scheme (Lau, Li, Xu, Liu, Kolar, arXiv:2406.13936,
cited in the paper's introduction as the local-gradient-method extension).

Each data-parallel worker takes H local AdamW steps on its own replica
between synchronizations; at sync, parameters and moments are averaged
(one all-reduce per H steps instead of per step), and the adaptive batch
statistic is computed from the *divergence of worker updates*:

    Δ_j = w_j^{(H)} − w^{(0)},   Δ = (1/J) Σ_j Δ_j
    var_l1 = (1/J) Σ_j ‖Δ_j − Δ‖²,  stat vs ‖Δ‖²

which plays the role eq. (5)'s per-worker gradient variance plays in
DDP-Norm: high inter-worker divergence ⇒ the local batches are too noisy ⇒
Algorithm 1 grows them.  Same controller, same rounding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.norm_test import (
    tree_sqdiff, tree_sqnorm, worker_variance_stats_flat)
from repro.distributed.flatbuf import FlatLayout
from repro.optim.adamw import AdamWConfig, init_adamw, adamw_update
from repro.distributed.params import param_pspecs
from repro.distributed.sharding import manual_data_rules, use_sharding_rules
from repro.compat import shard_map
from repro.distributed.train_step import _rules_for, _batch_pspec, _manual_axes
from repro.launch.mesh import data_axes


def make_local_sgd_step(model, opt_cfg: AdamWConfig, mesh, *,
                        stats_impl: str = "tree",
                        params_like=None, jit: bool = True):
    """Returns wrap(batch_like) -> jitted round function:
        round(params, opt_state, batch, lr) -> (params', opt', metrics)
    where batch leaves are (H, B_global, ...) — H local steps per sync.

    stats_impl='flat' computes the update-divergence statistic (‖Δ_j − Δ‖²
    and ‖Δ‖²) via the single-pass fused kernel over bucketed flat buffers
    (DESIGN §9) instead of the leaf-by-leaf sqdiff + sqnorm double pass."""
    if stats_impl not in ("tree", "flat"):
        raise ValueError(f"stats_impl must be 'tree' or 'flat', got {stats_impl!r}")
    daxes = data_axes(mesh)
    manual = _manual_axes(mesh, daxes)
    rules = manual_data_rules(_rules_for(mesh), manual)

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # one layout per step signature: the update-divergence trees (Δ_j, Δ)
    # are param-shaped, so they pack through the params layout
    layout = (FlatLayout.from_tree(params_like) if stats_impl == "flat"
              else None)

    def inner(params, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            def local_step(carry, mb):
                p, o = carry
                (loss, _), g = jax.value_and_grad(
                    lambda q: model.loss(q, mb), has_aux=True)(p)
                p, o, _ = adamw_update(p, g, o, opt_cfg, lr)
                return (p, o), loss

            (p_j, o_j), losses = jax.lax.scan(local_step, (params, opt_state),
                                              batch)
            # inter-worker update divergence (the adaptive-batch statistic)
            delta_j = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                p_j, params)
            delta = jax.tree.map(lambda x: jax.lax.pmean(x, daxes), delta_j)
            if stats_impl == "flat":
                # fused single-pass pair over bucketed flat buffers: pmean of
                # the local scalar + ‖Δ‖², one read of Δ_j and Δ (the shared
                # layout means each tree is packed exactly once)
                var_l1, dsq, _ = worker_variance_stats_flat(
                    delta_j, delta, daxes, layout=layout)
            else:
                var_l1 = jax.lax.pmean(tree_sqdiff(delta_j, delta), daxes)
                dsq = tree_sqnorm(delta)
            # synchronize: average replicas (params AND moments)
            p_avg = jax.tree.map(lambda x: jax.lax.pmean(x, daxes), p_j)
            o_avg = {
                "m": jax.tree.map(lambda x: jax.lax.pmean(x, daxes), o_j["m"]),
                "v": jax.tree.map(lambda x: jax.lax.pmean(x, daxes), o_j["v"]),
                "count": o_j["count"],
            }
            loss = jax.lax.pmean(jnp.mean(losses), daxes)
        metrics = {"loss": loss, "var_l1": var_l1, "grad_sqnorm": dsq,
                   "aux": jnp.zeros((), jnp.float32),
                   "grad_norm": jnp.sqrt(dsq)}
        return p_avg, o_avg, metrics

    p_specs = param_pspecs(params_like, mesh, fsdp=False)
    opt_like = jax.eval_shape(init_adamw, params_like)
    o_specs = {"m": p_specs, "v": p_specs, "count": P()}

    def wrap(batch_like):
        sm = shard_map(
            inner, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params_like),
                      jax.tree.map(lambda _: P(), opt_like),
                      _batch_pspec(batch_like, daxes), P()),
            out_specs=(jax.tree.map(lambda _: P(), params_like),
                       jax.tree.map(lambda _: P(), opt_like),
                       {"loss": P(), "var_l1": P(), "grad_sqnorm": P(),
                        "aux": P(), "grad_norm": P()}),
            axis_names=set(manual), check_vma=False)
        if not jit:
            return sm
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                       is_leaf=lambda s: isinstance(s, P))
        return jax.jit(
            sm,
            in_shardings=(ns(p_specs), ns(o_specs),
                          ns(_batch_pspec(batch_like, daxes)), None),
            out_shardings=(ns(p_specs), ns(o_specs), None),
            donate_argnums=(0, 1))

    return wrap, p_specs, o_specs
