"""Communication-efficient local-update training with adaptive batch sizes —
the paper's companion scheme (Lau, Li, Xu, Liu, Kolar, arXiv:2406.13936,
cited in the paper's introduction as the local-gradient-method extension).

Each data-parallel worker takes H local AdamW steps on its own replica
between synchronizations; at sync, parameters and moments are averaged
(one all-reduce per H steps instead of per step), and the adaptive batch
statistic is computed from the *divergence of worker updates*:

    Δ_j = w_j^{(H)} − w^{(0)},   Δ = (1/J) Σ_j Δ_j
    var_l1 = (1/J) Σ_j ‖Δ_j − Δ‖²,  stat vs ‖Δ‖²

which plays the role eq. (5)'s per-worker gradient variance plays in
DDP-Norm: high inter-worker divergence ⇒ the local batches are too noisy ⇒
Algorithm 1 grows them.  Same controller, same rounding.

`params_impl='flat'` (DESIGN §10) keeps the replica flat-RESIDENT through
the whole round: every local step differentiates
`layout.unflatten_for_grad`, so local gradients are born flat, the fused
buffer AdamW updates the buffers in place, and the update-divergence
statistic is a plain buffer subtraction — the round performs ZERO packs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.norm_test import (
    tree_sqdiff, tree_sqnorm, worker_variance_stats_buffers,
    worker_variance_stats_flat)
from repro.distributed.flatbuf import FlatLayout
from repro.optim.adamw import (
    AdamWConfig, init_adamw, init_adamw_flat, adamw_update,
    adamw_update_buffers)
from repro.distributed.params import param_pspecs
from repro.distributed.sharding import (
    flat_buffer_specs, manual_data_rules, use_sharding_rules)
from repro.compat import shard_map
from repro.distributed.train_step import (
    _rules_for, _batch_pspec, _manual_axes, _check_params_impl)
from repro.launch.mesh import data_axes


def make_local_sgd_step(model, opt_cfg: AdamWConfig, mesh, *,
                        stats_impl: str = "tree",
                        params_impl: str = "tree",
                        params_like=None, jit: bool = True):
    """Returns wrap(batch_like) -> jitted round function:
        round(params, opt_state, batch, lr) -> (params', opt', metrics)
    where batch leaves are (H, B_global, ...) — H local steps per sync.

    stats_impl='flat' computes the update-divergence statistic (‖Δ_j − Δ‖²
    and ‖Δ‖²) via the single-pass fused kernel over bucketed flat buffers
    (DESIGN §9) instead of the leaf-by-leaf sqdiff + sqnorm double pass.

    params_impl='flat' makes the replica flat-resident for the whole round
    (DESIGN §10): local gradients are born flat, the buffer AdamW runs per
    bucket, Δ_j/Δ are buffer subtractions, and sync averages buffers —
    zero packs per round.  Requires a flat optimizer state
    (`init_adamw_flat`); the shared layout is exposed as
    `wrap.flat_layout`."""
    if stats_impl not in ("tree", "flat"):
        raise ValueError(f"stats_impl must be 'tree' or 'flat', got {stats_impl!r}")
    _check_params_impl(params_impl)
    if params_impl == "flat" and stats_impl == "tree":
        # unlike the train-step builders there is no tree-ORACLE tail over
        # flat params here: the flat round always runs the buffer AdamW, so
        # accepting this combo would silently give flat semantics under a
        # tree label (and a tree opt state would mismatch the flat o_specs)
        raise ValueError("local-SGD has no tree-oracle tail over flat "
                         "params; use stats_impl='flat' with "
                         "params_impl='flat'")
    daxes = data_axes(mesh)
    manual = _manual_axes(mesh, daxes)
    rules = manual_data_rules(_rules_for(mesh), manual)

    if params_like is None:
        params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # one layout per step signature: the update-divergence trees (Δ_j, Δ)
    # are param-shaped, so they pack through the params layout (replicas are
    # per-worker whole copies here — no shard divisor)
    layout = (FlatLayout.from_tree(params_like)
              if (stats_impl == "flat" or params_impl == "flat") else None)

    def inner_tree(params, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            def local_step(carry, mb):
                p, o = carry
                (loss, _), g = jax.value_and_grad(
                    lambda q: model.loss(q, mb), has_aux=True)(p)
                p, o, _ = adamw_update(p, g, o, opt_cfg, lr)
                return (p, o), loss

            (p_j, o_j), losses = jax.lax.scan(local_step, (params, opt_state),
                                              batch)
            # inter-worker update divergence (the adaptive-batch statistic)
            delta_j = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                p_j, params)
            delta = jax.tree.map(lambda x: jax.lax.pmean(x, daxes), delta_j)
            if stats_impl == "flat":
                # fused single-pass pair over bucketed flat buffers: pmean of
                # the local scalar + ‖Δ‖², one read of Δ_j and Δ (the shared
                # layout means each tree is packed exactly once)
                var_l1, dsq, _ = worker_variance_stats_flat(
                    delta_j, delta, daxes, layout=layout)
            else:
                var_l1 = jax.lax.pmean(tree_sqdiff(delta_j, delta), daxes)
                dsq = tree_sqnorm(delta)
            # synchronize: average replicas (params AND moments)
            p_avg = jax.tree.map(lambda x: jax.lax.pmean(x, daxes), p_j)
            o_avg = {
                "m": jax.tree.map(lambda x: jax.lax.pmean(x, daxes), o_j["m"]),
                "v": jax.tree.map(lambda x: jax.lax.pmean(x, daxes), o_j["v"]),
                "count": o_j["count"],
            }
            loss = jax.lax.pmean(jnp.mean(losses), daxes)
        metrics = {"loss": loss, "var_l1": var_l1, "grad_sqnorm": dsq,
                   "aux": jnp.zeros((), jnp.float32),
                   "grad_norm": jnp.sqrt(dsq)}
        return p_avg, o_avg, metrics

    def inner_flat(pb, opt_state, batch, lr):
        with use_sharding_rules(rules, mesh):
            def local_step(carry, mb):
                p, o = carry
                (loss, _), gb = jax.value_and_grad(
                    lambda q: model.loss(layout.unflatten_for_grad(q), mb),
                    has_aux=True)(p)
                new_p, new_m, new_v, count, _, _ = adamw_update_buffers(
                    list(p), list(gb), list(o["m"]), list(o["v"]),
                    opt_cfg, lr, o["count"])
                o = {"m": tuple(new_m), "v": tuple(new_v), "count": count}
                return (tuple(new_p), o), loss

            (p_j, o_j), losses = jax.lax.scan(local_step, (pb, opt_state),
                                              batch)
            # born-flat update divergence: plain buffer arithmetic, no pack
            # (the builder rejects tree stats over flat params, so the
            # fused buffer pair is the only statistics path here)
            delta_j = [a.astype(jnp.float32) - b.astype(jnp.float32)
                       for a, b in zip(p_j, pb)]
            delta = [jax.lax.pmean(x, daxes) for x in delta_j]
            var_l1, dsq = worker_variance_stats_buffers(delta_j, delta, daxes)
            p_avg = tuple(jax.lax.pmean(b, daxes) for b in p_j)
            o_avg = {
                "m": tuple(jax.lax.pmean(b, daxes) for b in o_j["m"]),
                "v": tuple(jax.lax.pmean(b, daxes) for b in o_j["v"]),
                "count": o_j["count"],
            }
            loss = jax.lax.pmean(jnp.mean(losses), daxes)
        metrics = {"loss": loss, "var_l1": var_l1, "grad_sqnorm": dsq,
                   "aux": jnp.zeros((), jnp.float32),
                   "grad_norm": jnp.sqrt(dsq)}
        return p_avg, o_avg, metrics

    if params_impl == "flat":
        inner = inner_flat
        # whole-replica buffers: replicated across workers like the tree
        # path (empty axes => flat_buffer_specs degrades to P() per bucket)
        bspecs = flat_buffer_specs(layout.num_buffers, ())
        p_specs = bspecs
        opt_like = jax.eval_shape(
            lambda p: init_adamw_flat(p, layout=layout), params_like)
        o_specs = {"m": bspecs, "v": bspecs, "count": P()}
    else:
        inner = inner_tree
        p_specs = param_pspecs(params_like, mesh, fsdp=False)
        opt_like = jax.eval_shape(init_adamw, params_like)
        o_specs = {"m": p_specs, "v": p_specs, "count": P()}

    # everything is replicated inside the manual region; the flat p_specs
    # are already all-P(), the tree specs must be stripped to P()
    p_sm_specs = (p_specs if params_impl == "flat"
                  else jax.tree.map(lambda _: P(), params_like))

    def wrap(batch_like):
        sm = shard_map(
            inner, mesh=mesh,
            in_specs=(p_sm_specs,
                      jax.tree.map(lambda _: P(), opt_like),
                      _batch_pspec(batch_like, daxes), P()),
            out_specs=(p_sm_specs,
                       jax.tree.map(lambda _: P(), opt_like),
                       {"loss": P(), "var_l1": P(), "grad_sqnorm": P(),
                        "aux": P(), "grad_norm": P()}),
            axis_names=set(manual), check_vma=False)
        if not jit:
            return sm
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                       is_leaf=lambda s: isinstance(s, P))
        return jax.jit(
            sm,
            in_shardings=(ns(p_specs), ns(o_specs),
                          ns(_batch_pspec(batch_like, daxes)), None),
            out_shardings=(ns(p_specs), ns(o_specs), None),
            donate_argnums=(0, 1))

    wrap.flat_layout = layout
    return wrap, p_specs, o_specs
