"""Model configuration covering every assigned architecture family.

One `ModelConfig` describes dense, MoE, SSM, hybrid (RG-LRU), encoder–decoder
(audio) and VLM backbones.  Per-layer heterogeneity (gemma2 local/global
alternation, recurrentgemma 2:1 recurrent:attention) is expressed with
`block_pattern`: the stack is `num_layers / len(block_pattern)` repeats of the
pattern, scanned over repeats for O(1) trace size.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

# Layer kinds usable in block_pattern.
ATTN = "attn"            # full/causal GQA attention
LOCAL_ATTN = "local"     # sliding-window GQA attention
MLA_ATTN = "mla"         # DeepSeek-V2 multi-head latent attention
RGLRU = "rglru"          # RecurrentGemma recurrent block
SSD = "ssd"              # Mamba-2 state-space duality block

LAYER_KINDS = (ATTN, LOCAL_ATTN, MLA_ATTN, RGLRU, SSD)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # ffn width of each routed expert
    num_shared_experts: int = 0
    shared_d_expert: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # Which layers are MoE: every layer by default; first_dense skips layer 0
    # (DeepSeek-V2 keeps layer 0 dense).
    first_dense: int = 0


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => no q compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # 0 => d_model
    conv_width: int = 4
    c_constant: float = 8.0       # the fixed `c` in a = exp(-c*softplus(Λ)*r)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder (audio) models."""

    num_layers: int = 6
    num_frames: int = 1500        # stub frontend output length
    # encoder reuses d_model/num_heads/d_ff of the main config


@dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend: input_specs() provides embeddings directly."""

    kind: str = "none"            # none | audio_stub | vision_stub
    num_prefix_tokens: int = 0    # VLM: patch tokens prepended to text


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads

    block_pattern: tuple[str, ...] = (ATTN,)
    # unscanned layers before the scanned repeats; used for heterogeneous
    # prefixes (DeepSeek-V2 dense layer 0, RecurrentGemma's 38 = 2 + 12*3).
    # Prefix layers are always dense (never MoE).
    prefix_pattern: tuple[str, ...] = ()
    pos_embed: str = "rope"       # rope | sinusoidal | none
    mlp_kind: str = "swiglu"      # swiglu | geglu | gelu | relu2 | none
    norm_kind: str = "rmsnorm"    # rmsnorm | layernorm
    post_attn_norm: bool = False  # gemma2-style extra norms
    tie_embeddings: bool = True

    rope_theta: float = 10000.0
    sliding_window: int = 4096
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    scale_embed: bool = False     # gemma: embed * sqrt(d_model)
    qk_norm: bool = False

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: FrontendConfig = FrontendConfig()

    # structure: scan over layer repeats (O(1) trace) or python-unroll
    # (O(L) trace; required for faithful HLO cost analysis — XLA counts a
    # while-loop body once, so the dry-run unrolls).
    scan_layers: bool = True

    # numerics
    dtype: str = "float32"        # activation dtype
    param_dtype: str = "float32"
    remat: str = "none"           # none | full
    xent_chunk: int = 0           # 0 => unchunked cross-entropy

    # serving
    long_context_window: int = 4096   # sliding-window serving mode for long_500k
    native_subquadratic: bool = False # SSM/hybrid: long_500k without windowing

    # citation for the config (source paper / model card)
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        scanned = self.num_layers - len(self.prefix_pattern)
        assert scanned % len(self.block_pattern) == 0, (
            f"{self.name}: {scanned} scanned layers not divisible by "
            f"pattern length {len(self.block_pattern)}"
        )
        for kind in self.block_pattern + self.prefix_pattern:
            assert kind in LAYER_KINDS, kind

    @property
    def num_repeats(self) -> int:
        return (self.num_layers - len(self.prefix_pattern)) // len(self.block_pattern)

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts MoE top-k experts."""
        d = self.d_model
        layers = [(k, False) for k in self.prefix_pattern]
        layers += [(k, self.moe is not None) for k in self.block_pattern] * self.num_repeats
        n = self.vocab_size * d            # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        n += sum(self._layer_params(k, m, active_only) for k, m in layers)
        n += d                             # final norm
        if self.encoder is not None:
            enc_layer = self._layer_params(ATTN, False, active_only) \
                - (d * self.num_heads * self.head_dim
                   + 2 * d * self.num_kv_heads * self.head_dim
                   + self.num_heads * self.head_dim * d + d)  # no cross-attn in encoder
            n += self.encoder.num_layers * enc_layer + d
        return int(n)

    def _layer_params(self, kind: str, moe_layer: bool, active_only: bool) -> int:
        d = self.d_model
        p = 2 * d
        if kind in (ATTN, LOCAL_ATTN):
            q = self.num_heads * self.head_dim
            kv = self.num_kv_heads * self.head_dim
            p += d * q + 2 * d * kv + q * d
        elif kind == MLA_ATTN:
            m = self.mla
            qd = self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            if m.q_lora_rank:
                p += d * m.q_lora_rank + m.q_lora_rank * qd
            else:
                p += d * qd
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.num_heads * m.v_head_dim * d
        elif kind == RGLRU:
            w = self.rglru.lru_width or d
            p += 2 * d * w + w * d + 2 * w * w + 3 * w + self.rglru.conv_width * w
        elif kind == SSD:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            p += d * (2 * di + 2 * s.state_dim + nh) + di * d
            p += s.conv_width * (di + 2 * s.state_dim)
        if kind != SSD and self.mlp_kind != "none":
            p += self._mlp_params(active_only, moe_layer)
        if self.encoder is not None:
            q = self.num_heads * self.head_dim
            kv = self.num_kv_heads * self.head_dim
            p += d * q + 2 * d * kv + q * d + d
        return p

    def _mlp_params(self, active_only: bool, moe_layer: bool = True) -> int:
        d = self.d_model
        if self.moe is not None and moe_layer:
            m = self.moe
            n_routed = m.top_k if active_only else m.num_experts
            per_expert = 3 * d * m.d_expert if self.mlp_kind in ("swiglu", "geglu") else 2 * d * m.d_expert
            n = n_routed * per_expert + d * m.num_experts  # router
            if m.num_shared_experts:
                n += m.num_shared_experts * 3 * d * m.shared_d_expert
            return n
        mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        return mult * d * self.d_ff
