"""Decoder block: dispatch over layer kinds (attn/local/mla/rglru/ssd),
pre/post norms, dense-MLP or MoE feed-forward, residuals.

Every block exposes:
  * block_full(params, x, positions, cfg, kind, moe_layer, collect_cache)
        -> (x, aux_loss, cache | None)        # training / prefill
  * block_decode(params, x, cache, pos, cfg, kind, moe_layer, ring)
        -> (x, aux_loss, new_cache)           # single-token serving
  * init_block / init_block_cache
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models import attention as attn_lib
from repro.models import mla as mla_lib
from repro.models import rglru as rglru_lib
from repro.models import ssd as ssd_lib
from repro.models.config import ModelConfig, ATTN, LOCAL_ATTN, MLA_ATTN, RGLRU, SSD
from repro.models.mlp import init_mlp, apply_mlp
from repro.models.moe import init_moe, moe_apply
from repro.models.norms import init_norm, apply_norm
from repro.models.common import split_keys
from repro.distributed.sharding import maybe_shard


def _has_mlp(cfg: ModelConfig, kind: str) -> bool:
    return cfg.mlp_kind != "none" and kind != SSD


def init_block(key, cfg: ModelConfig, kind: str, moe_layer: bool):
    k_attn, k_mlp, k_n1, k_n2, k_n3, k_n4 = split_keys(key, 6)
    d, dtype = cfg.d_model, cfg.p_dtype
    p = {"pre_norm": init_norm(k_n1, d, cfg.norm_kind, dtype)}
    if kind in (ATTN, LOCAL_ATTN):
        p["attn"] = attn_lib.init_attention(
            k_attn, d, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype)
    elif kind == MLA_ATTN:
        p["attn"] = mla_lib.init_mla(k_attn, d, cfg.num_heads, cfg.mla, dtype)
    elif kind == RGLRU:
        p["rec"] = rglru_lib.init_rglru(k_attn, d, cfg.rglru, dtype)
    elif kind == SSD:
        p["ssd"] = ssd_lib.init_ssd(k_attn, d, cfg.ssm, dtype)
    else:
        raise ValueError(kind)
    if cfg.post_attn_norm:
        p["post_norm"] = init_norm(k_n2, d, cfg.norm_kind, dtype)
    if _has_mlp(cfg, kind):
        p["mlp_norm"] = init_norm(k_n3, d, cfg.norm_kind, dtype)
        if moe_layer:
            p["mlp"] = init_moe(k_mlp, d, cfg.moe, cfg.mlp_kind, dtype)
        else:
            p["mlp"] = init_mlp(k_mlp, d, cfg.d_ff, cfg.mlp_kind, dtype)
        if cfg.post_attn_norm:
            p["post_mlp_norm"] = init_norm(k_n4, d, cfg.norm_kind, dtype)
    return p


def _mixer_full(params, x, positions, cfg: ModelConfig, kind: str,
                collect_cache: bool, causal: bool = True):
    """Sequence mixer (attention or recurrence) over a full sequence."""
    cache = None
    rope_theta = cfg.rope_theta if cfg.pos_embed == "rope" else 0.0
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.sliding_window if kind == LOCAL_ATTN else 0
        out = attn_lib.attend_full(
            params["attn"], x, positions, rope_theta=rope_theta,
            softcap=cfg.attn_logit_softcap, window=window, causal=causal,
            qk_norm=cfg.qk_norm)
        if collect_cache:
            q, k, v = attn_lib._project_qkv(
                params["attn"], x, positions, rope_theta, cfg.qk_norm)
            cache = {"k": k, "v": v}
    elif kind == MLA_ATTN:
        out = mla_lib.mla_full(params["attn"], x, positions, cfg.mla)
        if collect_cache:
            c_kv, k_rope = mla_lib._latents(params["attn"], x, positions, cfg.mla)
            cache = {"c_kv": c_kv, "k_rope": k_rope}
    elif kind == RGLRU:
        out = rglru_lib.rglru_block(params["rec"], x, cfg.rglru)
        # (prefill state collection for RG-LRU is handled by the decode path)
    elif kind == SSD:
        out = ssd_lib.ssd_block(params["ssd"], x, cfg.ssm)
    else:
        raise ValueError(kind)
    return out, cache


def block_full(params, x, positions, cfg: ModelConfig, kind: str,
               moe_layer: bool, collect_cache: bool = False, causal: bool = True):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(params["pre_norm"], x, cfg.norm_kind)
    mixed, cache = _mixer_full(params, h, positions, cfg, kind, collect_cache, causal)
    if cfg.post_attn_norm:
        mixed = apply_norm(params["post_norm"], mixed, cfg.norm_kind)
    # TP boundary: `mixed` is the post-all-reduce output of the row-parallel
    # projection.  Under remat="tp_boundary" these named tensors are saved so
    # the backward recompute never re-runs the forward all-reduces (§Perf-1.3).
    mixed = checkpoint_name(mixed, "tp_out")
    mixed = maybe_shard(mixed, "batch", "act_seq", "embed")
    x = x + mixed
    if _has_mlp(cfg, kind):
        h = apply_norm(params["mlp_norm"], x, cfg.norm_kind)
        if moe_layer:
            h, aux = moe_apply(params["mlp"], h, cfg.moe)
        else:
            h = apply_mlp(params["mlp"], h, cfg.mlp_kind)
        if cfg.post_attn_norm:
            h = apply_norm(params["post_mlp_norm"], h, cfg.norm_kind)
        h = checkpoint_name(h, "tp_out")
        h = maybe_shard(h, "batch", "act_seq", "embed")
        x = x + h
    return x, aux, cache


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int, dtype):
    if kind in (ATTN, LOCAL_ATTN):
        length = min(cache_len, cfg.sliding_window) if kind == LOCAL_ATTN else cache_len
        return attn_lib.init_cache(batch, length, cfg.num_kv_heads, cfg.head_dim, dtype)
    if kind == MLA_ATTN:
        return mla_lib.init_mla_cache(batch, cache_len, cfg.mla, dtype)
    if kind == RGLRU:
        return rglru_lib.init_rglru_state(batch, cfg.d_model, cfg.rglru, dtype)
    if kind == SSD:
        return ssd_lib.init_ssd_state(batch, cfg.d_model, cfg.ssm, dtype)
    raise ValueError(kind)


def block_decode(params, x, cache, pos, cfg: ModelConfig, kind: str,
                 moe_layer: bool, ring: bool = False):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(params["pre_norm"], x, cfg.norm_kind)
    if kind in (ATTN, LOCAL_ATTN):
        # local-attn caches are rings by construction (length == window)
        is_ring = ring or kind == LOCAL_ATTN
        rope_theta = cfg.rope_theta if cfg.pos_embed == "rope" else 0.0
        mixed, new_cache = attn_lib.attend_decode(
            params["attn"], h, cache, pos, rope_theta=rope_theta,
            softcap=cfg.attn_logit_softcap, ring=is_ring, qk_norm=cfg.qk_norm)
    elif kind == MLA_ATTN:
        mixed, new_cache = mla_lib.mla_decode(params["attn"], h, cache, pos, cfg.mla, ring=ring)
    elif kind == RGLRU:
        mixed, new_cache = rglru_lib.rglru_decode(params["rec"], h, cache, cfg.rglru)
    elif kind == SSD:
        mixed, new_cache = ssd_lib.ssd_decode(params["ssd"], h, cache, cfg.ssm)
    else:
        raise ValueError(kind)
    if cfg.post_attn_norm:
        mixed = apply_norm(params["post_norm"], mixed, cfg.norm_kind)
    x = x + mixed
    if _has_mlp(cfg, kind):
        h = apply_norm(params["mlp_norm"], x, cfg.norm_kind)
        if moe_layer:
            h, aux = moe_apply(params["mlp"], h, cfg.moe,
                               capacity_factor=max(2.0, cfg.moe.capacity_factor))
        else:
            h = apply_mlp(params["mlp"], h, cfg.mlp_kind)
        if cfg.post_attn_norm:
            h = apply_norm(params["post_mlp_norm"], h, cfg.norm_kind)
        x = x + h
    return x, aux, new_cache
