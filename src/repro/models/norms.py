"""RMSNorm / LayerNorm (pure-pytree params)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import ones_init, zeros_init


def init_norm(key, d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": ones_init(key, (d,), dtype)}
    elif kind == "layernorm":
        return {"scale": ones_init(key, (d,), dtype), "bias": zeros_init(key, (d,), dtype)}
    raise ValueError(kind)


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * (1.0 / jnp.sqrt(var + eps))
        y = y * params["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) / jnp.sqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return y.astype(dtype)
