"""Dense MLP variants: SwiGLU / GeGLU / GELU / squared-ReLU (Nemotron-4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, split_keys
from repro.distributed.sharding import maybe_shard


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype):
    k1, k2, k3 = split_keys(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": normal_init(k1, (d_model, d_ff), dtype),
            "w_up": normal_init(k2, (d_model, d_ff), dtype),
            "w_down": normal_init(k3, (d_ff, d_model), dtype),
        }
    elif kind in ("gelu", "relu2"):
        return {
            "w_up": normal_init(k1, (d_model, d_ff), dtype),
            "w_down": normal_init(k2, (d_ff, d_model), dtype),
        }
    raise ValueError(kind)


def apply_mlp(params, x, kind: str):
    if kind in ("swiglu", "geglu"):
        gate = jnp.einsum("btd,df->btf", x, params["w_gate"].astype(x.dtype))
        up = jnp.einsum("btd,df->btf", x, params["w_up"].astype(x.dtype))
        gate = maybe_shard(gate, "batch", "seq", "ffn")
        act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jnp.einsum("btd,df->btf", x, params["w_up"].astype(x.dtype))
        h = maybe_shard(h, "batch", "seq", "ffn")
        if kind == "gelu":
            h = jax.nn.gelu(h)
        elif kind == "relu2":
            h = jnp.square(jax.nn.relu(h))
        else:
            raise ValueError(kind)
    out = jnp.einsum("btf,fd->btd", h, params["w_down"].astype(x.dtype))
    return maybe_shard(out, "batch", "seq", "embed")
