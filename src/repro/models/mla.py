"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Training/prefill uses the expanded form; decode uses the *absorbed* form that
attends directly in the compressed latent space (the whole point of MLA: the
KV cache stores only `c_kv` (rank 512) plus the shared RoPE key, instead of
full per-head K/V).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, split_keys
from repro.models.norms import init_norm, apply_norm
from repro.models.embeddings import apply_rope
from repro.models.attention import causal_mask, NEG_INF
from repro.models.config import MLAConfig
from repro.distributed.sharding import maybe_shard


def init_mla(key, d_model: int, num_heads: int, m: MLAConfig, dtype):
    keys = split_keys(key, 8)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {}
    if m.q_lora_rank:
        p["w_dq"] = normal_init(keys[0], (d_model, m.q_lora_rank), dtype)
        p["q_norm"] = init_norm(keys[0], m.q_lora_rank, "rmsnorm", dtype)
        p["w_uq"] = normal_init(keys[1], (m.q_lora_rank, num_heads, qk_dim), dtype)
    else:
        p["w_q"] = normal_init(keys[1], (d_model, num_heads, qk_dim), dtype)
    p["w_dkv"] = normal_init(keys[2], (d_model, m.kv_lora_rank), dtype)
    p["kv_norm"] = init_norm(keys[3], m.kv_lora_rank, "rmsnorm", dtype)
    p["w_krope"] = normal_init(keys[4], (d_model, m.qk_rope_head_dim), dtype)
    p["w_uk"] = normal_init(keys[5], (m.kv_lora_rank, num_heads, m.qk_nope_head_dim), dtype)
    p["w_uv"] = normal_init(keys[6], (m.kv_lora_rank, num_heads, m.v_head_dim), dtype)
    p["w_o"] = normal_init(keys[7], (num_heads, m.v_head_dim, d_model), dtype)
    return p


def _queries(params, x, positions, m: MLAConfig):
    if "w_dq" in params:
        cq = jnp.einsum("btd,dr->btr", x, params["w_dq"].astype(x.dtype))
        cq = apply_norm(params["q_norm"], cq, "rmsnorm")
        q = jnp.einsum("btr,rhk->bthk", cq, params["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("btd,dhk->bthk", x, params["w_q"].astype(x.dtype))
    q = maybe_shard(q, "batch", "seq", "heads", None)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, 10000.0)
    return q_nope, q_rope


def _latents(params, x, positions, m: MLAConfig):
    c_kv = jnp.einsum("btd,dr->btr", x, params["w_dkv"].astype(x.dtype))
    c_kv = apply_norm(params["kv_norm"], c_kv, "rmsnorm")
    k_rope = jnp.einsum("btd,dr->btr", x, params["w_krope"].astype(x.dtype))
    # shared rope key: single head
    k_rope = apply_rope(k_rope[:, :, None, :], positions, 10000.0)[:, :, 0, :]
    return c_kv, k_rope


def _mla_attend(q_nope, q_rope, k_nope, k_rope, v, m: MLAConfig,
                causal: bool, offset=0):
    """One (possibly chunked) MLA attention: q over full kv."""
    t, s = q_nope.shape[1], k_nope.shape[1]
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = jnp.einsum("bthn,bshn->bhts", q_nope, k_nope)
    logits += jnp.einsum("bthr,bsr->bhts", q_rope, k_rope)
    logits = logits.astype(jnp.float32) * scale
    if causal:
        mask = causal_mask(t, s, offset=offset)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhts,bshv->bthv", probs, v)


def mla_full(params, x, positions, m: MLAConfig, causal: bool = True,
             q_chunk: int = 512):
    """Expanded-form MLA over a full sequence (training / prefill); queries
    are chunk-scanned for long sequences (flash-style memory bound)."""
    b, t, _ = x.shape
    q_nope, q_rope = _queries(params, x, positions, m)
    c_kv, k_rope = _latents(params, x, positions, m)
    k_nope = jnp.einsum("btr,rhn->bthn", c_kv, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("btr,rhv->bthv", c_kv, params["w_uv"].astype(x.dtype))
    if t >= 2048 and t % q_chunk == 0:
        nc = t // q_chunk
        qn = jnp.moveaxis(q_nope.reshape(b, nc, q_chunk, *q_nope.shape[2:]), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(b, nc, q_chunk, *q_rope.shape[2:]), 1, 0)

        def body(carry, xs):
            qni, qri, ci = xs
            return carry, _mla_attend(qni, qri, k_nope, k_rope, v, m, causal,
                                      offset=ci * q_chunk)

        body = jax.checkpoint(body)
        _, out = jax.lax.scan(body, None, (qn, qr, jnp.arange(nc)))
        out = jnp.moveaxis(out, 0, 1).reshape(b, t, *out.shape[3:])
    else:
        out = _mla_attend(q_nope, q_rope, k_nope, k_rope, v, m, causal)
    out = jnp.einsum("bthv,hvd->btd", out, params["w_o"].astype(x.dtype))
    return maybe_shard(out, "batch", "seq", "embed")


def init_mla_cache(batch: int, cache_len: int, m: MLAConfig, dtype):
    return {
        "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype),
    }


def mla_decode(params, x, cache, pos, m: MLAConfig, ring: bool = False):
    """Absorbed-form single-token decode against the latent cache.  `pos` is
    a scalar int32, or a (b,) int32 vector of per-row positions (continuous-
    batching serving — each row writes and masks its own timeline)."""
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _queries(params, x, positions, m)          # (b,1,h,*)
    c_new, kr_new = _latents(params, x, positions, m)           # (b,1,r)
    cache_len = cache["c_kv"].shape[1]
    slot = pos % cache_len if ring else pos
    if per_row:
        rows = jnp.arange(b)
        c_kv = cache["c_kv"].at[rows, slot].set(
            c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[rows, slot].set(
            kr_new[:, 0].astype(cache["k_rope"].dtype))
    else:
        c_kv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, slot, 0))
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, slot, 0))
    # absorb W_uk into the query: attend in latent space
    q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, params["w_uk"].astype(x.dtype))
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = jnp.einsum("bthr,bsr->bhts", q_lat, c_kv.astype(x.dtype))
    logits += jnp.einsum("bthr,bsr->bhts", q_rope, k_rope.astype(x.dtype))
    logits = logits.astype(jnp.float32) * scale
    kpos = jnp.arange(cache_len)
    ppos = pos[:, None] if per_row else pos
    if ring:
        valid = (kpos <= ppos) | (ppos >= cache_len)
    else:
        valid = kpos <= ppos
    logits = jnp.where(valid[:, None, None, :] if per_row
                       else valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhts,bsr->bthr", probs, c_kv.astype(x.dtype))
    out = jnp.einsum("bthr,rhv->bthv", out_lat, params["w_uv"].astype(x.dtype))
    out = jnp.einsum("bthv,hvd->btd", out, params["w_o"].astype(x.dtype))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
