"""Public model API: a thin functional wrapper around the transformer stack."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.common import count_params


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key) -> dict:
        return tfm.init_params(key, self.cfg)

    def loss(self, params, batch):
        return tfm.loss_fn(params, batch, self.cfg)

    def logits(self, params, batch):
        hidden, _, offset, _ = tfm.forward(params, batch, self.cfg)
        if offset:
            hidden = hidden[:, offset:]
        return tfm._logits(params, hidden, self.cfg)

    def prefill(self, params, batch):
        return tfm.prefill(params, batch, self.cfg)

    def init_cache(self, batch: int, cache_len: int, ring: bool = False):
        return tfm.init_decode_cache(self.cfg, batch, cache_len, ring=ring)

    def decode_step(self, params, cache, tokens, pos, ring: bool = False):
        return tfm.decode_step(params, cache, tokens, pos, self.cfg, ring=ring)

    def num_params(self, params=None) -> int:
        if params is not None:
            return count_params(params)
        return self.cfg.param_count()


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
