"""Decoder-only / encoder-decoder transformer stack.

Structure: `num_repeats` repeats of `cfg.block_pattern` are scanned with
`lax.scan` (per-pattern-position parameters stacked along a leading repeat
axis) so trace size is O(pattern), not O(layers) — essential for the 60-layer
dry-runs.  MoE models may keep the first `moe.first_dense` layers as
unscanned dense "prefix" blocks (DeepSeek-V2 keeps layer 0 dense).

Cross-entropy is computed in sequence chunks (`cfg.xent_chunk`) so the
(batch, seq, vocab) logits tensor is never materialized — with 256k vocabs the
full tensor would dominate HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.common import split_keys
from repro.models.config import ModelConfig
from repro.models.embeddings import (
    init_embedding, embed_tokens, unembed, sinusoidal_positions, sinusoidal_at)
from repro.models.norms import init_norm, apply_norm
from repro.models.attention import init_attention, cross_attend, precompute_cross_kv
from repro.distributed.sharding import maybe_shard


# ------------------------------------------------------------------ init ----

def _layer_plan(cfg: ModelConfig):
    """(prefix_kinds, prefix_moe_flags, pattern_kinds, pattern_moe_flags, repeats)"""
    prefix_kinds = cfg.prefix_pattern
    pattern = cfg.block_pattern
    repeats = cfg.num_repeats
    prefix_moe = tuple(False for _ in prefix_kinds)   # prefix layers stay dense
    pattern_moe = tuple(cfg.moe is not None for _ in pattern)
    return prefix_kinds, prefix_moe, pattern, pattern_moe, repeats


def _init_one_block(key, cfg, kind, moe_layer):
    p = blk.init_block(key, cfg, kind, moe_layer)
    if cfg.encoder is not None:  # decoder cross-attention sub-layer
        kc, kn = split_keys(key, 2)
        p["cross_norm"] = init_norm(kn, cfg.d_model, cfg.norm_kind, cfg.p_dtype)
        p["cross_attn"] = init_attention(
            kc, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.p_dtype)
    return p


def init_params(key, cfg: ModelConfig):
    prefix_kinds, prefix_moe, pattern, pattern_moe, repeats = _layer_plan(cfg)
    k_embed, k_prefix, k_blocks, k_norm, k_unembed, k_enc = split_keys(key, 6)
    params = {"embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model, cfg.p_dtype)}

    if prefix_kinds:
        keys = split_keys(k_prefix, len(prefix_kinds))
        params["prefix_blocks"] = [
            _init_one_block(k, cfg, kind, m)
            for k, kind, m in zip(keys, prefix_kinds, prefix_moe)
        ]

    stacked = []
    pos_keys = split_keys(k_blocks, len(pattern))
    for pk, kind, moe_layer in zip(pos_keys, pattern, pattern_moe):
        rep_keys = jnp.stack(split_keys(pk, repeats))
        stacked.append(jax.vmap(
            lambda kk: _init_one_block(kk, cfg, kind, moe_layer))(rep_keys))
    params["blocks"] = stacked

    params["final_norm"] = init_norm(k_norm, cfg.d_model, cfg.norm_kind, cfg.p_dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(k_unembed, cfg.vocab_size, cfg.d_model, cfg.p_dtype)

    if cfg.encoder is not None:
        enc_keys = split_keys(k_enc, cfg.encoder.num_layers + 1)
        params["encoder"] = {
            "blocks": [blk.init_block(k, cfg, "attn", False) for k in enc_keys[:-1]],
            "final_norm": init_norm(enc_keys[-1], cfg.d_model, cfg.norm_kind, cfg.p_dtype),
        }
    return params


# --------------------------------------------------------------- encoder ----

def encode(params, frames, cfg: ModelConfig):
    """Whisper-style encoder over stub frame embeddings (b, nf, d)."""
    nf = frames.shape[1]
    x = frames + sinusoidal_positions(nf, cfg.d_model, frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(nf, dtype=jnp.int32), frames.shape[:2])
    for p in params["encoder"]["blocks"]:
        x, _, _ = blk.block_full(p, x, positions, cfg, "attn", False, causal=False)
    x = apply_norm(params["encoder"]["final_norm"], x, cfg.norm_kind)
    return x


# ----------------------------------------------------------------- stack ----

def _apply_cross(p, x, enc, cfg):
    if enc is not None and "cross_attn" in p:
        h = apply_norm(p["cross_norm"], x, cfg.norm_kind)
        x = x + cross_attend(p["cross_attn"], h, enc)
    return x


def run_stack(params, x, positions, cfg: ModelConfig, enc=None, collect_cache=False):
    """Run prefix + scanned blocks. Returns (hidden, aux, caches|None)."""
    prefix_kinds, prefix_moe, pattern, pattern_moe, repeats = _layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    prefix_caches = []
    for p, kind, moe_layer in zip(params.get("prefix_blocks", []), prefix_kinds, prefix_moe):
        x, aux, cache = blk.block_full(p, x, positions, cfg, kind, moe_layer,
                                       collect_cache=collect_cache)
        x = _apply_cross(p, x, enc, cfg)
        aux_total += aux
        prefix_caches.append(cache)

    def body(carry, layer_params):
        x, aux_total = carry
        caches = []
        for p, kind, moe_layer in zip(layer_params, pattern, pattern_moe):
            x, aux, cache = blk.block_full(p, x, positions, cfg, kind, moe_layer,
                                           collect_cache=collect_cache)
            x = _apply_cross(p, x, enc, cfg)
            aux_total += aux
            caches.append(cache)
        out = tuple(caches) if collect_cache else None
        return (x, aux_total), out

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "tp_boundary":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names("tp_out"))

    if cfg.scan_layers:
        (x, aux_total), scanned_caches = jax.lax.scan(
            body, (x, aux_total), tuple(params["blocks"]))
    else:
        outs = []
        for r in range(repeats):
            layer_params = tuple(jax.tree.map(lambda a: a[r], blk_p)
                                 for blk_p in params["blocks"])
            (x, aux_total), out = body((x, aux_total), layer_params)
            outs.append(out)
        scanned_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
                          if collect_cache else None)
    x = apply_norm(params["final_norm"], x, cfg.norm_kind)
    caches = None
    if collect_cache:
        caches = {"prefix": prefix_caches, "scanned": scanned_caches}
    return x, aux_total, caches


# ------------------------------------------------------------------ loss ----

def _logits(params, hidden, cfg: ModelConfig):
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    src = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(src, hidden, tied_table=tied)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def _xent(logits, labels):
    """Cross entropy with label -1 == masked. Returns (sum_loss, count)."""
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def token_loss(params, hidden, labels, cfg: ModelConfig):
    """Chunked softmax cross-entropy over the sequence axis."""
    chunk = cfg.xent_chunk
    t = hidden.shape[1]
    if chunk <= 0 or t <= chunk or t % chunk != 0:
        logits = _logits(params, hidden, cfg)
        s, c = _xent(logits, labels)
        return s / jnp.maximum(c, 1)

    nch = t // chunk
    h = hidden.reshape(hidden.shape[0], nch, chunk, -1).swapaxes(0, 1)
    l = labels.reshape(labels.shape[0], nch, chunk).swapaxes(0, 1)

    def body(carry, xs):
        s, c = carry
        hc, lc = xs
        logits = _logits(params, hc, cfg)
        ds, dc = _xent(logits, lc)
        return (s + ds, c + dc), None

    body = jax.checkpoint(body)
    (s, c), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2, (h, l))
    return s / jnp.maximum(c, 1)


# ------------------------------------------------------------- model API ----

def _assemble_inputs(batch, params, cfg: ModelConfig):
    """Embed tokens and any stub-frontend embeddings. Returns (x, positions,
    label_offset, enc)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg.scale_embed, cfg.d_model)
    x = x.astype(cfg.act_dtype)
    enc = None
    offset = 0
    if cfg.frontend.kind == "vision_stub":
        patches = batch["patch_embeds"].astype(cfg.act_dtype)   # (b, np, d)
        x = jnp.concatenate([patches, x], axis=1)
        offset = patches.shape[1]
    elif cfg.frontend.kind == "audio_stub":
        enc = encode(params, batch["frames"].astype(cfg.act_dtype), cfg)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal_positions(t, cfg.d_model, x.dtype)[None]
    x = maybe_shard(x, "batch", "seq", "embed")
    return x, positions, offset, enc


def forward(params, batch, cfg: ModelConfig):
    """Full forward -> (hidden, aux, offset, enc)."""
    x, positions, offset, enc = _assemble_inputs(batch, params, cfg)
    hidden, aux, _ = run_stack(params, x, positions, cfg, enc=enc)
    return hidden, aux, offset, enc


def loss_fn(params, batch, cfg: ModelConfig):
    """Mean next-token cross entropy (+ MoE aux). labels use -1 as mask."""
    hidden, aux, offset, _ = forward(params, batch, cfg)
    labels = batch["labels"]
    if offset:  # VLM: prefix patch positions carry no labels
        pad = jnp.full((labels.shape[0], offset), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = token_loss(params, hidden, labels, cfg)
    return loss + aux, {"xent": loss, "aux": aux}


def prefill(params, batch, cfg: ModelConfig):
    """Prefill for serving: returns (last_token_logits, caches, enc_cross_kv)."""
    x, positions, offset, enc = _assemble_inputs(batch, params, cfg)
    hidden, _, caches = run_stack(params, x, positions, cfg, enc=enc, collect_cache=True)
    logits = _logits(params, hidden[:, -1:, :], cfg)
    return logits[:, 0], caches


# ------------------------------------------------------------- decoding ----

def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int, ring: bool = False):
    """Fresh decode cache pytree.  ring=True (long_500k serving mode) bounds
    full-attention caches to cfg.long_context_window."""
    prefix_kinds, _, pattern, _, repeats = _layer_plan(cfg)
    dtype = cfg.act_dtype

    def one(kind):
        length = cache_len
        if ring and kind in ("attn", "mla"):
            length = min(cache_len, cfg.long_context_window)
        return blk.init_block_cache(cfg, kind, batch, length, dtype)

    cache = {
        "prefix": [one(k) for k in prefix_kinds],
        "scanned": [
            jax.tree.map(lambda x: jnp.broadcast_to(x, (repeats,) + x.shape), one(kind))
            for kind in pattern
        ],
    }
    if cfg.encoder is not None:
        # cross-attention K/V per decoder layer (prefix + scanned)
        nf = cfg.encoder.num_frames
        kv = lambda: {
            "k": jnp.zeros((batch, nf, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, nf, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
        cache["cross_prefix"] = [kv() for _ in prefix_kinds]
        cache["cross_scanned"] = [
            jax.tree.map(lambda x: jnp.broadcast_to(x, (repeats,) + x.shape), kv())
            for _ in pattern
        ]
    return cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, ring: bool = False):
    """One decode step. tokens: (b,) int32; pos: scalar int32 global position
    or a (b,) int32 vector of per-row positions (continuous-batching serving).
    Returns (logits (b, vocab), new_cache)."""
    prefix_kinds, prefix_moe, pattern, pattern_moe, repeats = _layer_plan(cfg)
    x = embed_tokens(params["embed"], tokens[:, None], cfg.scale_embed, cfg.d_model)
    x = x.astype(cfg.act_dtype)
    if cfg.pos_embed == "sinusoidal":
        emb = sinusoidal_at(jnp.asarray(pos), cfg.d_model, x.dtype)
        x = x + (emb[:, None, :] if emb.ndim == 2 else emb[None, None, :])

    new_prefix = []
    for i, (p, kind, moe_layer) in enumerate(
            zip(params.get("prefix_blocks", []), prefix_kinds, prefix_moe)):
        x, _, c = blk.block_decode(p, x, cache["prefix"][i], pos, cfg, kind,
                                   moe_layer, ring=ring)
        if "cross_prefix" in cache and "cross_attn" in p:
            h = apply_norm(p["cross_norm"], x, cfg.norm_kind)
            x = x + cross_attend(p["cross_attn"], h, cache["cross_prefix"][i])
        new_prefix.append(c)

    def body(x, xs):
        layer_params, layer_caches, cross_caches = xs
        new_caches = []
        for j, (p, kind, moe_layer) in enumerate(zip(layer_params, pattern, pattern_moe)):
            x, _, c = blk.block_decode(p, x, layer_caches[j], pos, cfg, kind,
                                       moe_layer, ring=ring)
            if cross_caches is not None and "cross_attn" in p:
                h = apply_norm(p["cross_norm"], x, cfg.norm_kind)
                x = x + cross_attend(p["cross_attn"], h, cross_caches[j])
            new_caches.append(c)
        return x, tuple(new_caches)

    has_cross = "cross_scanned" in cache
    if cfg.scan_layers:
        if has_cross:
            xs = (tuple(params["blocks"]), tuple(cache["scanned"]),
                  tuple(cache["cross_scanned"]))
            x, new_scanned = jax.lax.scan(body, x, xs)
        else:
            x, new_scanned = jax.lax.scan(
                lambda xx, ys: body(xx, (ys[0], ys[1], None)),
                x, (tuple(params["blocks"]), tuple(cache["scanned"])))
    else:
        outs = []
        for r in range(repeats):
            lp = tuple(jax.tree.map(lambda a: a[r], bp) for bp in params["blocks"])
            lc = tuple(jax.tree.map(lambda a: a[r], bc) for bc in cache["scanned"])
            cc = (tuple(jax.tree.map(lambda a: a[r], xc)
                        for xc in cache["cross_scanned"]) if has_cross else None)
            x, out = body(x, (lp, lc, cc))
            outs.append(out)
        new_scanned = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = apply_norm(params["final_norm"], x, cfg.norm_kind)
    logits = _logits(params, x, cfg)[:, 0]
    new_cache = dict(cache)
    new_cache["prefix"] = new_prefix
    new_cache["scanned"] = list(new_scanned)
    return logits, new_cache
