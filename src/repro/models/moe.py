"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch,
shared experts (DeepSeek-V2), switch-style load-balance aux loss.

Dispatch is the TPU-friendly sort/capacity scheme: token-expert pairs are
sorted by expert id, truncated to a static per-expert capacity, batched into
an (E, C, d) tensor and processed with a single (E,d,f) einsum — MXU-dense,
expert dim sharded over the `model` mesh axis (expert parallelism).  Tokens
over capacity are dropped (standard GShard/Switch behaviour); capacity_factor
controls the drop rate.

Sharding-critical structure (measured in EXPERIMENTS.md §Perf-2):
  * dispatch groups are batch rows (GShard "groups") so the argsort is
    shard-local under any batch sharding;
  * both dispatch and combine are *slot-major* — the expert-sharded (E, C, d)
    tensor is produced by a gather (local fwd, cheap bwd) and consumed by a
    scatter-add whose only collective is an (n, d) all-reduce.  Pair-major
    formulations make GSPMD replicate (n·k, d) buffers (24 GB/layer on
    deepseek-v2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, split_keys
from repro.models.config import MoEConfig
from repro.distributed.sharding import maybe_shard


def init_moe(key, d_model: int, m: MoEConfig, mlp_kind: str, dtype):
    k_r, k_g, k_u, k_d, k_s = split_keys(key, 5)
    p = {
        "router": normal_init(k_r, (d_model, m.num_experts), dtype),
        "w_gate": normal_init(k_g, (m.num_experts, d_model, m.d_expert), dtype),
        "w_up": normal_init(k_u, (m.num_experts, d_model, m.d_expert), dtype),
        "w_down": normal_init(k_d, (m.num_experts, m.d_expert, d_model), dtype),
    }
    if m.num_shared_experts:
        width = m.num_shared_experts * m.shared_d_expert
        ks1, ks2, ks3 = split_keys(k_s, 3)
        p["shared"] = {
            "w_gate": normal_init(ks1, (d_model, width), dtype),
            "w_up": normal_init(ks2, (d_model, width), dtype),
            "w_down": normal_init(ks3, (width, d_model), dtype),
        }
    return p


def _capacity(num_tokens: int, m: MoEConfig, capacity_factor: float) -> int:
    c = int(capacity_factor * num_tokens * m.top_k / m.num_experts)
    return max(min(c, num_tokens), 1)


def moe_apply(params, x, m: MoEConfig, *, capacity_factor: float | None = None,
              normalize_gates: bool = True):
    """x: (b, t, d) -> (out, aux_loss).

    Dispatch groups are batch rows (GShard "groups"): the sort and the
    capacity budget are per-row, so with the batch sharded over the data axes
    the entire dispatch is shard-local — no global argsort collectives
    (§Perf-2.2).  Capacity C = factor·t·top_k/E per row."""
    b, t, d = x.shape

    def row(xt):
        return _moe_row(params, xt, m, capacity_factor, normalize_gates)

    y, aux = jax.vmap(row)(x)
    return maybe_shard(y, "batch", "seq", "embed"), jnp.mean(aux)


def _moe_row(params, xt, m: MoEConfig, capacity_factor, normalize_gates):
    """One dispatch group. xt: (n, d) -> ((n, d), aux)."""
    n, d = xt.shape
    dt = xt.dtype
    router_logits = jnp.einsum("nd,de->ne", xt, params["router"].astype(dt))
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, m.top_k)               # (n, k)
    if normalize_gates:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    # switch-style load balance loss over all-k assignments
    one_hot_k = jax.nn.one_hot(expert_idx, m.num_experts, dtype=jnp.float32)  # (n,k,E)
    frac_tokens = jnp.mean(jnp.sum(one_hot_k, axis=1), axis=0)      # (E,)
    frac_probs = jnp.mean(probs, axis=0)                            # (E,)
    aux = m.num_experts * jnp.sum(frac_tokens * frac_probs) * m.router_aux_coef

    # ---- sort-based capacity dispatch ----
    cap = _capacity(n, m, capacity_factor if capacity_factor is not None
                    else m.capacity_factor)
    pair_expert = expert_idx.reshape(-1)                            # (n*k,)
    pair_gate = gates.reshape(-1).astype(dt)
    pair_token = jnp.repeat(jnp.arange(n), m.top_k)
    order = jnp.argsort(pair_expert)                                # stable
    se, sg, st = pair_expert[order], pair_gate[order], pair_token[order]
    # position of each pair within its expert group
    counts = jnp.bincount(se, length=m.num_experts)                 # (E,)
    starts = jnp.cumsum(counts) - counts                            # (E,)
    pos_in_expert = jnp.arange(n * m.top_k) - starts[se]
    keep = pos_in_expert < cap
    dest = jnp.where(keep, se * cap + pos_in_expert, n * m.top_k)   # overflow slot

    # slot -> token map (small int scatters; dest is unique by construction)
    n_slots = m.num_experts * cap
    slot_token = jnp.full((n_slots + 1,), n, jnp.int32).at[dest].set(
        st, unique_indices=True, mode="drop")[:n_slots]
    slot_gate = jnp.zeros((n_slots + 1,), dt).at[dest].set(
        jnp.where(keep, sg, 0), unique_indices=True, mode="drop")[:n_slots]

    # ---- slot-major dispatch (§Perf-2.3): GATHER from the (replicated)
    # token array with expert-sharded slot indices.  Forward is shard-local;
    # backward is a partial scatter-add + one (n,d) all-reduce.  The previous
    # scatter-set formulation replicated its 10 GB/layer cotangent with an
    # all-gather on deepseek-v2.
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)
    slot_token_ec = maybe_shard(slot_token.reshape(m.num_experts, cap),
                                "experts", None)
    edx = xt_pad[slot_token_ec]                                     # (E, C, d)
    edx = maybe_shard(edx, "experts", None, "embed")

    gate_w = params["w_gate"].astype(dt)
    up_w = params["w_up"].astype(dt)
    down_w = params["w_down"].astype(dt)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", edx, gate_w)) * jnp.einsum(
        "ecd,edf->ecf", edx, up_w)
    h = maybe_shard(h, "experts", None, None)
    eout = jnp.einsum("ecf,efd->ecd", h, down_w)                    # (E, C, d)

    # ---- slot-major combine (§Perf-2.1): scatter-add from the expert-sharded
    # slot axis into token space.  The pair-major formulation
    # (`eout_flat[dest] * gate`) gathers from a sharded operand with
    # replicated indices, which GSPMD implements by ALL-REDUCING the whole
    # (n·k, d) gather result — 24 GB/layer on deepseek-v2.  Slot-major keeps
    # the big operand sharded and all-reduces only the (n, d) output.
    sg_ec = maybe_shard(slot_gate.reshape(m.num_experts, cap), "experts", None)
    contrib = eout * sg_ec[..., None]                               # (E, C, d)
    # NOTE: a token can occupy up to top_k slots -> indices NOT unique here
    y = jnp.zeros((n + 1, d), dt).at[slot_token_ec].add(contrib, mode="drop")[:n]

    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(jnp.einsum("nd,df->nf", xt, sh["w_gate"].astype(dt)))
        hs = hs * jnp.einsum("nd,df->nf", xt, sh["w_up"].astype(dt))
        y = y + jnp.einsum("nf,fd->nd", hs, sh["w_down"].astype(dt))

    return y, aux.astype(jnp.float32)
