"""Shared helpers for model layers: initializers, dtype casting, params utils."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, dtype, stddev: float = 0.02):
    return (stddev * jax.random.normal(key, shape)).astype(dtype)


def fan_in_init(key, shape, dtype, fan_in: int | None = None):
    fi = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(jnp.maximum(fi, 1))).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))
