"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked dual form: within a chunk the recurrence is
materialized as masked matmuls (MXU-dense); across chunks a short
`lax.scan` carries the (heads, head_dim, state) SSM state — O(T/Q) sequential
steps instead of O(T).  Decode is the exact single-step recurrence.

Layout notes (TPU adaptation): heads are sharded over the `model` mesh axis;
chunk size defaults to 128 so intra-chunk matmuls are MXU-aligned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, zeros_init, split_keys
from repro.models.config import SSMConfig
from repro.distributed.sharding import maybe_shard


def init_ssd(key, d_model: int, s: SSMConfig, dtype):
    di = s.d_inner(d_model)
    nh = s.num_heads(d_model)
    conv_ch = di + 2 * s.state_dim          # conv over [x, B, C]
    keys = split_keys(key, 5)
    return {
        # fused input projection -> [z, x, B, C, dt]
        "w_in": normal_init(keys[0], (d_model, 2 * di + 2 * s.state_dim + nh), dtype),
        "conv_w": normal_init(keys[1], (s.conv_width, conv_ch), dtype),
        "conv_b": zeros_init(keys[1], (conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": zeros_init(keys[2], (nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "w_out": normal_init(keys[3], (di, d_model), dtype),
    }


def _split_proj(params, x, s: SSMConfig, d_model: int):
    di = s.d_inner(d_model)
    nh = s.num_heads(d_model)
    proj = jnp.einsum("btd,dp->btp", x, params["w_in"].astype(x.dtype))
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * s.state_dim], axis=-1)
    return z, xbc, dt, di, nh


def _causal_conv(x, w, b):
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def _gated_out(params, y, z, x_dtype):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y / jnp.sqrt(var + 1e-6) * params["norm_scale"].astype(jnp.float32)
    return jnp.einsum("btf,fd->btd", y.astype(x_dtype), params["w_out"].astype(x_dtype))


def ssd_block(params, x, s: SSMConfig, initial_state=None, return_state=False):
    """Chunked SSD over a full sequence. x: (b,t,d)."""
    b, t, d_model = x.shape
    z, xbc, dt_raw, di, nh = _split_proj(params, x, s, d_model)
    xbc = _causal_conv(xbc, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype))
    xs, B, C = jnp.split(xbc, [di, di + s.state_dim], axis=-1)
    p = s.head_dim
    xs = xs.reshape(b, t, nh, p)
    xs = maybe_shard(xs, "batch", "seq", "ssm_heads", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])  # (b,t,nh)
    a = -jnp.exp(params["a_log"])                                   # (nh,)
    dA = dt * a[None, None, :]                                      # log decay per step

    q = s.chunk_size
    assert t % q == 0, f"seq {t} must be divisible by chunk {q}"
    nc = t // q
    # reshape into chunks
    xs_c = xs.reshape(b, nc, q, nh, p).astype(jnp.float32)
    B_c = B.reshape(b, nc, q, s.state_dim).astype(jnp.float32)
    C_c = C.reshape(b, nc, q, s.state_dim).astype(jnp.float32)
    dt_c = dt.reshape(b, nc, q, nh)
    dA_c = dA.reshape(b, nc, q, nh)

    cum = jnp.cumsum(dA_c, axis=2)                                  # (b,nc,q,nh)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]             # (b,nc,q_i,q_j,nh)
    causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # mask BEFORE exp: non-causal entries have positive log-decay -> exp
    # overflows -> 0*inf = NaN in the backward pass
    seg = jnp.where(causal, seg, -1e30)
    decay = jnp.exp(seg)

    # intra-chunk: y[i] = sum_j<=i (C_i . B_j) decay(i,j) dt_j x_j
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)                    # (b,nc,q,q)
    m = cb[:, :, :, :, None] * decay                                # (b,nc,q,q,nh)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", m, dt_c, xs_c)

    # chunk state contributions: S_c = sum_j exp(cum[-1]-cum[j]) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                 # (b,nc,q,nh)
    sc = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchnp", decay_to_end, dt_c, B_c, xs_c)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                         # (b,nc,nh)

    # scan over chunks carrying state (b, nh, n, p)
    if initial_state is None:
        s0 = jnp.zeros((b, nh, s.state_dim, p), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def step(state, inp):
        sc_c, cdec = inp                                            # (b,nh,n,p), (b,nh)
        new = state * cdec[:, :, None, None] + sc_c
        return new, state                                           # emit state *before* chunk

    sc_t = jnp.moveaxis(sc, 1, 0)                                   # (nc,b,nh,n,p)
    cdec_t = jnp.moveaxis(chunk_decay, 1, 0)                        # (nc,b,nh)
    final_state, prev_states = jax.lax.scan(step, s0, (sc_t, cdec_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                   # (b,nc,nh,n,p)

    # inter-chunk: y[i] += C_i . (decay_from_start(i) * S_prev)
    decay_from_start = jnp.exp(cum)                                 # (b,nc,q,nh)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", C_c, prev_states, decay_from_start)

    y = (y_intra + y_inter).reshape(b, t, nh, p)
    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, t, di)
    out = _gated_out(params, y, z, x.dtype)
    out = maybe_shard(out, "batch", "seq", "embed")
    if return_state:
        return out, final_state
    return out


def init_ssd_state(batch: int, d_model: int, s: SSMConfig, dtype):
    nh = s.num_heads(d_model)
    di = s.d_inner(d_model)
    return {
        "ssm": jnp.zeros((batch, nh, s.state_dim, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, di + 2 * s.state_dim), dtype),
    }


def ssd_decode(params, x, state, s: SSMConfig):
    """Exact single-step recurrence. x: (b,1,d)."""
    b, _, d_model = x.shape
    z, xbc, dt_raw, di, nh = _split_proj(params, x, s, d_model)
    conv_in = jnp.concatenate([state["conv"], xbc], axis=1)
    wconv = params["conv_w"].astype(x.dtype)
    xbc_t = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, wconv)
                        + params["conv_b"].astype(x.dtype))
    xs, B, C = jnp.split(xbc_t, [di, di + s.state_dim], axis=-1)
    p = s.head_dim
    xs = xs.reshape(b, nh, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"][None, :])  # (b,nh)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a[None, :])                                # (b,nh)
    Bf = B.astype(jnp.float32)
    new_state = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, Bf, xs)
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), new_state)
    y = y + params["d_skip"][None, :, None] * xs
    y = y.reshape(b, 1, di)
    out = _gated_out(params, y, z, x.dtype)
    return out, {"ssm": new_state, "conv": conv_in[:, 1:, :]}
