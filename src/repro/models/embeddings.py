"""Token embeddings, RoPE, and sinusoidal positions."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init
from repro.distributed.sharding import maybe_shard


def init_embedding(key, vocab: int, d: int, dtype):
    return {"table": normal_init(key, (vocab, d), dtype)}


def embed_tokens(params, tokens, scale: bool, d_model: int):
    table = maybe_shard(params["table"], "vocab", "embed")
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(jnp.sqrt(d_model), x.dtype)
    return x


def unembed(params, x, tied_table=None):
    """Project hidden states to vocab logits (tied or untied)."""
    table = tied_table if tied_table is not None else params["table"]
    table = maybe_shard(table, "vocab", "embed")
    return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))


# ---------------------------------------------------------------- RoPE ----

def rope_frequencies(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                       # (head_dim//2,)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                   # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_at(pos, d: int, dtype):
    """Sinusoidal embedding row(s) at (possibly traced) position `pos`:
    scalar -> (d,); a (b,) vector of per-row positions -> (b, d)."""
    log_timescale = jnp.log(10000.0) / (d // 2 - 1)
    inv_timescales = jnp.exp(-log_timescale * jnp.arange(d // 2, dtype=jnp.float32))
    if hasattr(pos, "astype"):
        p = pos.astype(jnp.float32)
        scaled = (p[:, None] if p.ndim == 1 else p) * inv_timescales
    else:
        scaled = float(pos) * inv_timescales
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1).astype(dtype)


def sinusoidal_positions(num_pos: int, d: int, dtype):
    """Whisper-style fixed sinusoidal embeddings, shape (num_pos, d)."""
    log_timescale = jnp.log(10000.0) / (d // 2 - 1)
    inv_timescales = jnp.exp(-log_timescale * jnp.arange(d // 2, dtype=jnp.float32))
    scaled = jnp.arange(num_pos, dtype=jnp.float32)[:, None] * inv_timescales[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1).astype(dtype)
