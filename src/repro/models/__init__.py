from repro.models.config import (
    ModelConfig, MoEConfig, MLAConfig, SSMConfig, RGLRUConfig,
    EncoderConfig, FrontendConfig,
)
from repro.models.model import Model, build_model

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RGLRUConfig",
    "EncoderConfig", "FrontendConfig", "Model", "build_model",
]
