"""RecurrentGemma / Griffin RG-LRU recurrent block [arXiv:2402.19427].

Block: two branches from d_model -> lru_width; branch A goes through GeLU,
branch B through a causal depthwise conv1d then the RG-LRU recurrence; the
branches are multiplied and projected back to d_model.

RG-LRU:  r_t = sigmoid(W_a x_t + b_a),  i_t = sigmoid(W_x x_t + b_x)
         a_t = exp(-c * softplus(Lambda) * r_t)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses `jax.lax.associative_scan` (log-depth, TPU friendly); decode is
a single fused recurrence step with conv ring state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, zeros_init, split_keys
from repro.models.config import RGLRUConfig
from repro.distributed.sharding import maybe_shard


def init_rglru(key, d_model: int, r: RGLRUConfig, dtype):
    w = r.lru_width or d_model
    keys = split_keys(key, 7)
    return {
        "w_branch_a": normal_init(keys[0], (d_model, w), dtype),
        "w_branch_b": normal_init(keys[1], (d_model, w), dtype),
        "conv_w": normal_init(keys[2], (r.conv_width, w), dtype),
        "conv_b": zeros_init(keys[2], (w,), dtype),
        "w_rg": normal_init(keys[3], (w, w), dtype, stddev=0.02),
        "b_rg": zeros_init(keys[3], (w,), dtype),
        "w_ig": normal_init(keys[4], (w, w), dtype, stddev=0.02),
        "b_ig": zeros_init(keys[4], (w,), dtype),
        # Lambda init so that a ~ uniform(0.9, 0.999) at r=1 (Griffin appendix)
        "lam": normal_init(keys[5], (w,), jnp.float32, stddev=0.5),
        "w_out": normal_init(keys[6], (w, d_model), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (b,t,w); w: (k,w)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _gates(params, x, c_constant):
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", x, params["w_rg"].astype(x.dtype))
                       + params["b_rg"].astype(x.dtype))
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", x, params["w_ig"].astype(x.dtype))
                       + params["b_ig"].astype(x.dtype))
    log_a = -c_constant * jax.nn.softplus(params["lam"])[None, None, :] * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.clip(1.0 - jnp.square(a), 1e-12)) * (
        i.astype(jnp.float32) * x.astype(jnp.float32))
    return a, gated_in


def rglru_scan(a, bx):
    """h_t = a_t h_{t-1} + bx_t over axis=1 via associative scan."""
    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r * b_l + b_r
    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_block(params, x, r: RGLRUConfig):
    """Full-sequence RG-LRU block. x: (b,t,d) -> (b,t,d)."""
    branch_a = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, params["w_branch_a"].astype(x.dtype)))
    u = jnp.einsum("btd,dw->btw", x, params["w_branch_b"].astype(x.dtype))
    u = maybe_shard(u, "batch", "seq", "lru_width")
    u = _causal_conv(u, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype))
    a, bx = _gates(params, u, r.c_constant)
    h = rglru_scan(a, bx).astype(x.dtype)
    y = branch_a * h
    out = jnp.einsum("btw,wd->btd", y, params["w_out"].astype(x.dtype))
    return maybe_shard(out, "batch", "seq", "embed")


def init_rglru_state(batch: int, d_model: int, r: RGLRUConfig, dtype):
    w = r.lru_width or d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, r.conv_width - 1, w), dtype),
    }


def rglru_decode(params, x, state, r: RGLRUConfig):
    """Single-token step. x: (b,1,d)."""
    branch_a = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, params["w_branch_a"].astype(x.dtype)))
    u = jnp.einsum("btd,dw->btw", x, params["w_branch_b"].astype(x.dtype))
    conv_in = jnp.concatenate([state["conv"], u], axis=1)          # (b, k, w)
    wconv = params["conv_w"].astype(x.dtype)
    u_conv = jnp.einsum("bkw,kw->bw", conv_in, wconv) + params["conv_b"].astype(x.dtype)
    u_conv = u_conv[:, None, :]
    a, bx = _gates(params, u_conv, r.c_constant)
    h = a[:, 0] * state["h"] + bx[:, 0]
    y = branch_a[:, 0] * h.astype(x.dtype)
    out = jnp.einsum("bw,wd->bd", y, params["w_out"].astype(x.dtype))[:, None, :]
    return out, {"h": h, "conv": conv_in[:, 1:, :]}
