"""GQA attention with RoPE, sliding windows, logit soft-capping and KV caches.

Three entry points:
  * `attend_full`   — training / prefill over a whole sequence (causal or not)
  * `attend_decode` — single-token decode against a KV cache
  * caches: `init_cache` (full-length) and ring-buffer sliding caches for the
    `long_500k` serving mode.

The pure-jnp path here is the reference; the Pallas flash kernel in
`repro.kernels.flash_attention` is the TPU drop-in for the same math and is
validated against this implementation in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, split_keys
from repro.models.embeddings import apply_rope
from repro.distributed.sharding import maybe_shard

NEG_INF = -2.0e38


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int, head_dim: int, dtype):
    kq, kk, kv, ko = split_keys(key, 4)
    return {
        "wq": normal_init(kq, (d_model, num_heads, head_dim), dtype),
        "wk": normal_init(kk, (d_model, num_kv_heads, head_dim), dtype),
        "wv": normal_init(kv, (d_model, num_kv_heads, head_dim), dtype),
        "wo": normal_init(ko, (num_heads, head_dim, d_model), dtype),
    }


def _project_qkv(params, x, positions, rope_theta, qk_norm: bool):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(x.dtype))
    q = maybe_shard(q, "batch", "seq", "heads", None)
    k = maybe_shard(k, "batch", "seq", "kv_heads", None)
    v = maybe_shard(v, "batch", "seq", "kv_heads", None)
    if qk_norm:
        q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
        k = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, softcap: float):
    """q: (b,t,h,dk) k/v: (b,s,kv,dk); GQA via head grouping. mask: (b,t,s) or (t,s)."""
    b, t, h, dk = q.shape
    kv = k.shape[2]
    # GQA via *kv-head expansion* (kv tensors are small) instead of grouping
    # q heads: the (b,t,kv,g,d) reshape breaks the `heads` sharding axis and
    # forced the SPMD partitioner into full-remat copies (see §Perf-1).
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
        k = maybe_shard(k, "batch", None, "heads", None)
        v = maybe_shard(v, "batch", None, "heads", None)
    logits = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(dk)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask is not None:
        # mask is (t,s) or (b,t,s) or (b,1,s); logits are (b,h,t,s)
        if mask.ndim == 2:
            mask = mask[None, None]
        elif mask.ndim == 3:
            mask = mask[:, None]
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhts,bshd->bthd", probs, v)
    return out


def _sdpa_grouped(q, k, v, mask, softcap: float):
    """Grouped-query attention for DECODE: q is reshaped to (b,t,kv,g,d) so
    the KV cache is read once, never expanded.  (Training uses `_sdpa`'s
    kv-expansion — see §Perf-1/§Perf-3: expansion is right when kv << t·d
    activations, wrong when the cache dominates, i.e. decode.)"""
    b, t, h, dk = q.shape
    kv = k.shape[2]
    g = h // kv
    q = q.reshape(b, t, kv, g, dk)
    logits = jnp.einsum("btkgd,bskd->bkgts", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(dk)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        elif mask.ndim == 3:
            mask = mask[:, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, t, h, dk)


def causal_mask(t: int, s: int, offset: int = 0, window: int = 0):
    """(t, s) boolean mask. q position i (global i+offset) sees kv j<=i+offset;
    with window>0 also j > i+offset-window."""
    qpos = jnp.arange(t)[:, None] + offset
    kpos = jnp.arange(s)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


Q_CHUNK = 512
CHUNK_THRESHOLD = 2048  # use q-chunked (flash-style) attention for t >= this


def _sdpa_chunked(q, k, v, softcap, causal, window, q_chunk=Q_CHUNK):
    """Memory-bounded attention: lax.scan over query chunks so the logits
    buffer is O(q_chunk · s) instead of O(t · s).  This is the jnp-level
    equivalent of the Pallas flash kernel (which replaces it on real TPU)."""
    b, t, h, dk = q.shape
    s = k.shape[1]
    assert t % q_chunk == 0, (t, q_chunk)
    nc = t // q_chunk
    qc = jnp.moveaxis(q.reshape(b, nc, q_chunk, h, dk), 1, 0)

    def body(carry, xs):
        qi, ci = xs
        mask = causal_mask(q_chunk, s, offset=ci * q_chunk, window=window) \
            if (causal or window > 0) else None
        return carry, _sdpa(qi, k, v, mask, softcap)

    body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, None, (qc, jnp.arange(nc) * 1))
    return jnp.moveaxis(out, 0, 1).reshape(b, t, h, dk)


def attend_full(params, x, positions, *, rope_theta, softcap=0.0, window=0,
                causal=True, qk_norm=False):
    """Self-attention over a full sequence (training / prefill)."""
    q, k, v = _project_qkv(params, x, positions, rope_theta, qk_norm)
    t = x.shape[1]
    if t >= CHUNK_THRESHOLD and t % Q_CHUNK == 0:
        out = _sdpa_chunked(q, k, v, softcap, causal, window)
    else:
        mask = causal_mask(t, t, 0, window) if causal else None
        out = _sdpa(q, k, v, mask, softcap)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(x.dtype))
    return maybe_shard(out, "batch", "seq", "embed")


def cross_attend(params, x, kv_source, *, softcap=0.0):
    """Encoder-decoder cross attention; kv_source either hidden states
    (b,s,d) or a precomputed {"k","v"} cache."""
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    if isinstance(kv_source, dict):
        k, v = kv_source["k"].astype(x.dtype), kv_source["v"].astype(x.dtype)
    else:
        k = jnp.einsum("bsd,dhk->bshk", kv_source, params["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", kv_source, params["wv"].astype(x.dtype))
    out = _sdpa(q, k, v, None, softcap)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(x.dtype),
                     preferred_element_type=x.dtype)
    return out


def precompute_cross_kv(params, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(enc_out.dtype))
    return {"k": k, "v": v}


# ------------------------------------------------------------- KV cache ----

def init_cache(batch: int, cache_len: int, num_kv_heads: int, head_dim: int, dtype):
    """Full-length cache (decode_32k) or ring buffer (long_500k windowed mode —
    pass cache_len=window)."""
    return {
        "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
    }


def attend_decode(params, x, cache, pos, *, rope_theta, softcap=0.0,
                  ring: bool = False, qk_norm=False):
    """Single-token decode. x: (b,1,d); pos: scalar int32 global position,
    or a (b,) int32 vector of PER-ROW positions (continuous-batching serving:
    each cache row advances on its own timeline, writes its own slot, and
    masks its own prefix).  Returns (out, new_cache).  `ring=True` treats the
    cache as a circular sliding-window buffer of length cache_len."""
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, positions, rope_theta, qk_norm)
    cache_len = cache["k"].shape[1]
    slot = pos % cache_len if ring else pos
    if per_row:
        rows = jnp.arange(b)
        k = cache["k"].at[rows, slot].set(k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[rows, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    else:
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    kpos = jnp.arange(cache_len)
    ppos = pos[:, None] if per_row else pos   # broadcasts over (b, cache_len)
    if ring:
        # valid slots: all once pos>=cache_len-1, else slots <= pos
        valid = kpos <= jnp.maximum(ppos, cache_len - 1)
        valid &= (kpos <= ppos) | (ppos >= cache_len)
    else:
        valid = kpos <= ppos
    mask = valid[:, None, :] if per_row else valid[None, None, :]
    out = _sdpa_grouped(q, k.astype(x.dtype), v.astype(x.dtype), mask, softcap)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(x.dtype),
                     preferred_element_type=x.dtype)
    return out, {"k": k, "v": v}
