"""CLI for the static analysis gate: ``python -m repro.analysis``.

Runs both layers (AST lint sweep + trace-only step-matrix invariant check)
and prints a report; ``--strict`` exits 1 on any unwaived finding (the CI
static-analysis job), ``--json`` emits the machine-readable report.
"""

from __future__ import annotations

import argparse
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level invariant checker + determinism/perf lint")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unwaived finding (the CI gate)")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable JSON report")
    ap.add_argument("--root", default=None,
                    help="repo root to lint (default: auto from this file)")
    ap.add_argument("--skip-lint", action="store_true",
                    help="run only the jaxpr invariant matrix")
    ap.add_argument("--skip-jaxpr", action="store_true",
                    help="run only the AST lint sweep")
    args = ap.parse_args(argv)

    from repro.analysis.findings import active, render_report
    from repro.analysis.lint import run_lint

    root = pathlib.Path(args.root) if args.root else \
        pathlib.Path(__file__).resolve().parents[3]
    findings, checked = [], {}
    if not args.skip_lint:
        lint = run_lint(root)
        findings.extend(lint)
        checked["lint_root"] = str(root)
        checked["lint_files"] = sum(
            1 for sub in ("src", "benchmarks") if (root / sub).exists()
            for _ in (root / sub).rglob("*.py"))
    if not args.skip_jaxpr:
        from repro.analysis.invariants import run_invariant_checks
        from repro.kernels.ops import flat_dispatch_info
        jx, jx_checked = run_invariant_checks()
        findings.extend(jx)
        checked.update(jx_checked)
        checked["dispatch"] = flat_dispatch_info()

    print(render_report(findings, checked=checked, as_json=args.json))
    return 1 if (args.strict and active(findings)) else 0


if __name__ == "__main__":
    sys.exit(main())
