"""CLI for the static analysis gate: ``python -m repro.analysis``.

Runs all three layers — the AST determinism/perf lint, the trace-only
step-matrix invariant check, and the cost-model + SPMD-divergence layer
(collective volume / analytic FLOPs / peak-memory watermark diffed
against the committed ``analysis_budget.json``) — and prints a report.
``--strict`` exits 1 on any unwaived finding (the CI static-analysis
job), ``--json`` emits the machine-readable report (the per-variant cost
metrics ride in ``checked.cost``), and ``--update-budget`` refreezes the
budget after an intentional cost change.
"""

from __future__ import annotations

import argparse
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level invariant checker + cost-model budget "
                    "gate + determinism/perf lint")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unwaived finding (the CI gate)")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable JSON report")
    ap.add_argument("--root", default=None,
                    help="repo root to lint (default: auto from this file)")
    ap.add_argument("--skip-lint", action="store_true",
                    help="skip the AST lint sweep")
    ap.add_argument("--skip-jaxpr", action="store_true",
                    help="skip the traced layers (invariants AND cost model)")
    ap.add_argument("--skip-cost", action="store_true",
                    help="skip layer 3 (cost model + divergence) only")
    ap.add_argument("--budget", default=None,
                    help="cost-budget baseline path (default: "
                         "<root>/analysis_budget.json)")
    ap.add_argument("--update-budget", action="store_true",
                    help="refreeze analysis_budget.json from this run's "
                         "measurements instead of diffing (the intentional-"
                         "change flow; commit the result)")
    args = ap.parse_args(argv)

    from repro.analysis.findings import active, render_report
    from repro.analysis.lint import run_lint

    root = pathlib.Path(args.root) if args.root else \
        pathlib.Path(__file__).resolve().parents[3]
    findings, checked = [], {}
    if not args.skip_lint:
        lint = run_lint(root)
        findings.extend(lint)
        checked["lint_root"] = str(root)
        checked["lint_files"] = sum(
            1 for sub in ("src", "benchmarks") if (root / sub).exists()
            for _ in (root / sub).rglob("*.py"))
    if not args.skip_jaxpr:
        from repro.analysis.invariants import build_variants, \
            run_invariant_checks
        from repro.kernels.ops import flat_dispatch_info
        variants = build_variants()
        jx, jx_checked = run_invariant_checks(variants=variants)
        findings.extend(jx)
        checked.update(jx_checked)
        checked["dispatch"] = flat_dispatch_info()
        if not args.skip_cost:
            from repro.analysis.costmodel import run_cost_checks
            from repro.analysis.divergence import run_divergence_checks
            budget = pathlib.Path(args.budget) if args.budget else \
                root / "analysis_budget.json"
            cost, cost_checked = run_cost_checks(
                budget, variants=variants, update=args.update_budget)
            findings.extend(cost)
            checked["cost"] = cost_checked
            div, div_checked = run_divergence_checks(variants)
            findings.extend(div)
            checked["divergence"] = div_checked

    print(render_report(findings, checked=checked, as_json=args.json))
    return 1 if (args.strict and active(findings)) else 0


if __name__ == "__main__":
    sys.exit(main())
