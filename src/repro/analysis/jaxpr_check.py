"""Jaxpr/MLIR-level primitives for the static invariant checker (DESIGN §13).

Everything here operates on TRACED artifacts only — jaxprs from
`jax.make_jaxpr` and StableHLO text from `.lower().as_text()` — never on
executed code.  The flat-buffer entry points bind a zero-cost marker
primitive (`flatbuf.layout_marker_p`) on their buffers, so pack/unflatten/
adjoint events are real equations these walkers can count *through* jit,
scan, shard_map, and custom_vjp boundaries — unlike the removed
Python-call proxy (`count_packs`), which only saw host-level calls.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import jax

LAYOUT_MARKER = "repro_layout_marker"

# Primitives that move data to the host (or run Python) mid-step: any of
# these inside a hot-path step graph is a per-step sync the schedules'
# measured step cost would silently absorb.
_HOST_PRIM_RE = re.compile(r"callback|debug_print|infeed|outfeed")


def iter_eqns(jaxpr):
    """Every equation in `jaxpr` and, recursively, in every sub-jaxpr
    carried by an equation's params (pjit/scan `jaxpr`, custom_vjp
    `call_jaxpr`, cond `branches`, shard_map bodies, ...)."""
    if hasattr(jaxpr, "jaxpr"):          # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    yield from iter_eqns(sub)


def trace(fn, *args, **kwargs):
    """Closed jaxpr of `fn` at the given abstract signature (no execution,
    no compilation; jitted callables keep their pjit eqn so shardings and
    donation flags remain inspectable)."""
    return jax.make_jaxpr(fn)(*args, **kwargs)


def count_layout_ops(target, *args, **kwargs) -> dict:
    """Count the flat-layout marker eqns in a traced graph.

    `target` is a jaxpr/ClosedJaxpr, or a callable traced at `*args`.
    Returns {"pack": [...], "unflatten": [...], "adjoint": [...]} — one
    entry per marker eqn, in jaxpr order, valued with the event's leaf
    count.  `len(result["pack"])` is the per-step flatten count the PR 3
    double-pack regression guard asserts on."""
    jaxpr = target if hasattr(target, "eqns") or hasattr(target, "jaxpr") \
        else trace(target, *args, **kwargs)
    out: dict = {"pack": [], "unflatten": [], "adjoint": []}
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name == LAYOUT_MARKER:
            out[eqn.params["kind"]].append(eqn.params["nleaves"])
    return out


def find_host_eqns(jaxpr) -> list[str]:
    """Names of equations that leave the device mid-graph: host callbacks,
    debug prints, infeed/outfeed, and Pallas calls forced into interpret
    mode at trace time (an interpreted kernel runs on host even on TPU)."""
    bad = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if _HOST_PRIM_RE.search(name):
            bad.append(name)
        elif name == "pallas_call" and eqn.params.get("interpret"):
            bad.append("pallas_call[interpret=True]")
    return bad


def top_pjit_params(jaxpr) -> dict | None:
    """Params of the outermost pjit eqn of a traced jitted callable (None
    when the trace has no pjit — e.g. a jit=False step).  Carries
    `in_shardings` (NamedSharding per flat input when explicit) and
    `donated_invars` (bool per flat input)."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pjit":
            return eqn.params
    return None


def in_specs(jaxpr) -> list | None:
    """PartitionSpec (or None when unspecified) per flat input of the
    outermost pjit eqn."""
    params = top_pjit_params(jaxpr)
    if params is None:
        return None
    return [getattr(s, "spec", None) for s in params["in_shardings"]]


# ----------------------------------------------------- lowered-MLIR side ----

@dataclass(frozen=True)
class ArgAttrs:
    """Attributes of one `@main` argument in lowered StableHLO text."""
    index: int
    aliased: bool          # XLA accepted the donation (tf.aliasing_output)
    sharding: str | None   # mhlo.sharding string, if any


def main_arg_attrs(lowered_text: str) -> list[ArgAttrs]:
    """Parse the `@main` signature of `.lower().as_text()` output.

    Donation that actually took effect annotates the argument with
    `tf.aliasing_output = N`; a donated input the compiler could NOT alias
    (shape/dtype matches no output — the donation silently does nothing)
    carries no attribute, which is exactly the regression this parser
    exists to catch."""
    start = lowered_text.index("@main(")
    # paren-balanced scan: attr strings never contain parens, but stop at
    # the signature's closing paren, not the first one
    depth, end = 0, None
    for i in range(start + len("@main"), len(lowered_text)):
        c = lowered_text[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    sig = lowered_text[start + len("@main("):end]
    out = []
    # each chunk spans one argument: its attrs (incl. quoted shardings with
    # braces inside) end before the next `%arg`
    for chunk in sig.split("%arg")[1:]:
        idx = int(chunk[:chunk.index(":")])
        m = re.search(r'mhlo\.sharding = "([^"]*)"', chunk)
        out.append(ArgAttrs(index=idx,
                            aliased="tf.aliasing_output" in chunk,
                            sharding=m.group(1) if m else None))
    return out


def donation_effective(jitted, args) -> tuple[list[ArgAttrs], list[int]]:
    """Lower (never execute) a jitted callable and report which flat inputs
    XLA actually aliased.  Returns (per-arg attrs, indices of donated-but-
    unaliased args) — the second list should be empty for every step whose
    donated buffers are meant to be updated in place."""
    traced = trace(jitted, *args)
    params = top_pjit_params(traced)
    donated = params["donated_invars"] if params else ()
    attrs = main_arg_attrs(jitted.lower(*args).as_text())
    if len(attrs) != len(donated):
        raise RuntimeError(
            f"lowered @main has {len(attrs)} args but the jaxpr has "
            f"{len(donated)} inputs — argument pruning would misalign the "
            f"donation check")
    dead = [i for i, (a, d) in enumerate(zip(attrs, donated))
            if d and not a.aliased]
    return attrs, dead


__all__ = ["ArgAttrs", "LAYOUT_MARKER", "count_layout_ops",
           "donation_effective", "find_host_eqns", "in_specs", "iter_eqns",
           "main_arg_attrs", "top_pjit_params", "trace"]
