"""AST determinism/perf lint (DESIGN §13, layer 2).

Each rule is a plugin registered with `@register_rule`: a pure function
from a parsed module to `(line, message)` findings, plus a path scope
(some hazards are only hazards in certain code — wall-clock reads are fine
in the training loop but not inside traced step code).  Intentional hits
are waived inline:

    something_hazardous()  # repro: allow(<rule-id>) — <reason>

(on the offending line or the line directly above).  Waived findings stay
in the report, flagged, but never fail the gate.

The rule set encodes this repo's actual regression history:

* ``hash-seed``       — PR 5: `hash(name)` seeded per-host RNGs; str hashes
                        are PYTHONHASHSEED-randomized per process, so every
                        host materialized a different batch.  `id()` is
                        equally run-dependent.
* ``wallclock-traced``— a `time.*` / `datetime.now` read inside traced or
                        fault-deterministic code either burns a host sync
                        or (under `REPRO_FAULTS`) breaks replayability.
* ``bare-interpret``  — a literal `interpret=True` pins a Pallas kernel to
                        host interpret mode on every backend; the backend
                        decision belongs to `kernels.resolve_interpret`.
* ``set-iter-order``  — iterating a set feeds PYTHONHASHSEED-dependent
                        order into whatever consumes it; traced code turns
                        that into per-process graph topologies (cache-key
                        and compiled-executable desync across hosts).
* ``unfenced-timing`` — PR 6: wall-clock spans around async dispatch
                        measured dispatch, not work.  A benchmark function
                        that reads the clock twice must fence with
                        `block_until_ready`.
* ``nonatomic-write`` — checkpoint/coordination files must be written
                        tmp-then-`os.replace` (crash atomicity, DESIGN
                        §12); `os.rename` fails on an existing target on
                        Windows and a plain in-place `open(..., "w")` can
                        be torn by a crash mid-write.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from repro.analysis.findings import Finding

WAIVER_RE = re.compile(
    r"#\s*repro:\s*allow\(\s*([a-zA-Z0-9_,\- ]+?)\s*\)\s*(?:[—–-]+\s*(.*))?$")

_RULES: list["LintRule"] = []


class LintRule:
    def __init__(self, rule_id: str, check, scope=None, doc: str = ""):
        self.id = rule_id
        self.check = check           # (tree, src, relpath) -> [(line, msg)]
        self.scope = scope           # (relpath: str) -> bool; None = all
        self.doc = doc

    def applies(self, relpath: str) -> bool:
        return self.scope is None or self.scope(relpath)


def register_rule(rule_id: str, scope=None):
    def deco(fn):
        _RULES.append(LintRule(rule_id, fn, scope, fn.__doc__ or ""))
        return fn
    return deco


def rules() -> list[LintRule]:
    return list(_RULES)


# --------------------------------------------------------------- helpers ----

def _call_name(node: ast.Call) -> str:
    """Dotted name of a call target: `time.monotonic`, `hash`, `os.replace`
    (best-effort; non-name targets come back empty)."""
    parts: list[str] = []
    f = node.func
    while isinstance(f, ast.Attribute):
        parts.append(f.attr)
        f = f.value
    if isinstance(f, ast.Name):
        parts.append(f.id)
    return ".".join(reversed(parts))


_WALLCLOCK = {"time.time", "time.monotonic", "time.perf_counter",
              "time.process_time", "time.time_ns", "time.monotonic_ns",
              "time.perf_counter_ns", "time.sleep",
              "datetime.now", "datetime.utcnow", "datetime.today",
              "datetime.datetime.now", "datetime.datetime.utcnow"}
# reads only (not sleep): what a timing span is made of
_CLOCK_READS = _WALLCLOCK - {"time.sleep"}


def _func_ranges(tree, name: str):
    """(start, end) line ranges of every function literally named `name`."""
    return [(n.lineno, n.end_lineno) for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n.name == name]


def _in_ranges(line: int, ranges) -> bool:
    return any(a <= line <= b for a, b in ranges)


def _path_in(*prefixes):
    norm = tuple(p.rstrip("/") for p in prefixes)
    return lambda rel: any(rel == p or rel.startswith(p + "/") for p in norm)


# ----------------------------------------------------------------- rules ----

@register_rule("hash-seed")
def _hash_seed(tree, src, relpath):
    """`hash()`/`id()` values are per-process (PYTHONHASHSEED / allocator):
    using them in seeds, cache keys, or filenames desyncs hosts.  Bodies of
    `__hash__` are exempt (delegating to `hash()` there is the protocol);
    anything else needs a waiver or a stable digest (`zlib.crc32`,
    `hashlib`)."""
    exempt = _func_ranges(tree, "__hash__")
    out = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in ("hash", "id")
                and not _in_ranges(node.lineno, exempt)):
            out.append((node.lineno,
                        f"{node.func.id}() is PYTHONHASHSEED/run-dependent; "
                        f"use a stable digest (zlib.crc32, hashlib) for "
                        f"seeds and cache keys"))
    return out


_TRACED_SCOPE = _path_in(
    "src/repro/kernels", "src/repro/models", "src/repro/optim",
    "src/repro/core", "src/repro/data",
    "src/repro/distributed/train_step.py",
    "src/repro/distributed/local_step.py",
    "src/repro/distributed/serve_step.py",
    "src/repro/distributed/flatbuf.py",
    "src/repro/distributed/params.py",
    "src/repro/distributed/sharding.py",
    "src/repro/testing/faults.py",
)


@register_rule("wallclock-traced", scope=_TRACED_SCOPE)
def _wallclock_traced(tree, src, relpath):
    """Wall-clock reads inside traced step code or the fault-deterministic
    harness: a traced `time.*` runs once at trace time (a silent constant),
    a host-side one syncs the device, and under `REPRO_FAULTS` any
    wall-clock dependence breaks deterministic replay."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_name(node) in _WALLCLOCK:
            out.append((node.lineno,
                        f"{_call_name(node)}() in traced/fault-deterministic "
                        f"code; thread times in as data or waive"))
    return out


_HOST_IDENTITY = {"jax.process_index", "process_index",
                  "jax.process_count", "process_count",
                  "jax.host_id", "host_id",
                  "socket.gethostname", "platform.node",
                  "os.getpid", "uuid.uuid4"}


@register_rule("host-divergence", scope=_TRACED_SCOPE)
def _host_divergence(tree, src, relpath):
    """Host-identity reads (process index/count, hostname, pid, uuid4)
    inside traced-scope code: a value that differs per rank feeding a
    traced computation produces per-rank graphs — ranks then disagree on
    collective order and deadlock (the SPMD-divergence class layer 3's
    `divergence.py` checks dynamically; this is the lexical half).
    Rank-dependent *data* belongs in collectives; rank-dependent
    *structure* is always a bug."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_name(node) in _HOST_IDENTITY:
            out.append((node.lineno,
                        f"{_call_name(node)}() is a per-rank value in "
                        f"traced-scope code; rank-dependent structure "
                        f"desyncs SPMD programs — hoist it to the launch "
                        f"layer or waive"))
    return out


@register_rule("bare-interpret",
               scope=lambda rel: rel != "src/repro/kernels/__init__.py")
def _bare_interpret(tree, src, relpath):
    """A literal `interpret=True` forces host interpret mode on every
    backend.  The backend decision belongs to `kernels.resolve_interpret`
    (explicit flag > REPRO_PALLAS_INTERPRET > autodetect) — pass its
    result instead."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    out.append((kw.value.lineno,
                                "bare interpret=True; route through "
                                "kernels.resolve_interpret"))
    return out


def _is_set_expr(node) -> bool:
    return (isinstance(node, (ast.Set, ast.SetComp))
            or (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ("set", "frozenset")))


@register_rule("set-iter-order")
def _set_iter_order(tree, src, relpath):
    """Iterating a set literal / set() result feeds PYTHONHASHSEED-dependent
    order downstream; in trace-adjacent code that means per-process graph
    topologies and cache keys.  Wrap the iterable in `sorted(...)`."""
    out = []
    iters = [n.iter for n in ast.walk(tree) if isinstance(n, ast.For)]
    iters += [gen.iter for n in ast.walk(tree)
              if isinstance(n, (ast.ListComp, ast.SetComp, ast.DictComp,
                                ast.GeneratorExp))
              for gen in n.generators]
    for it in iters:
        if _is_set_expr(it):
            out.append((it.lineno,
                        "iteration order of a set is PYTHONHASHSEED-"
                        "dependent; wrap in sorted(...)"))
    return out


@register_rule("unfenced-timing", scope=_path_in("benchmarks"))
def _unfenced_timing(tree, src, relpath):
    """A benchmark function that reads the clock more than once is timing a
    span; without a `block_until_ready` fence the span measures async
    dispatch, not device work (the PR 6 prefill-timing leak).  Functions
    with a single read (timestamping) are fine."""
    out = []

    class V(ast.NodeVisitor):
        def _do_func(self, node):
            reads, fenced = [], False
            stack = list(ast.iter_child_nodes(node))
            while stack:
                n = stack.pop()
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._do_func(n)       # nested functions fence themselves
                    continue
                if isinstance(n, ast.Call):
                    name = _call_name(n)
                    if name in _CLOCK_READS:
                        reads.append(n.lineno)
                    if name.endswith("block_until_ready"):
                        fenced = True
                if isinstance(n, ast.Attribute) \
                        and n.attr == "block_until_ready":
                    fenced = True
                stack.extend(ast.iter_child_nodes(n))
            if len(reads) >= 2 and not fenced:
                out.append((min(reads),
                            f"{len(reads)} clock reads with no "
                            f"block_until_ready fence in this function; the "
                            f"span times dispatch, not device work"))

        def visit_FunctionDef(self, node):
            self._do_func(node)

        visit_AsyncFunctionDef = visit_FunctionDef

    V().visit(tree)
    return out


_DURABLE_SCOPE = _path_in("src/repro/checkpoint",
                          "src/repro/distributed/coordination.py")


@register_rule("nonatomic-write", scope=_DURABLE_SCOPE)
def _nonatomic_write(tree, src, relpath):
    """Checkpoint/coordination files must land atomically: write a temp
    sibling, fsync, `os.replace` (DESIGN §12).  `os.rename` is not atomic
    over an existing target on all platforms, and an in-place
    `open(path, "w")` with no `os.replace` in the same function tears the
    previous contents on a crash mid-write."""
    out = []

    def write_mode(call: ast.Call) -> bool:
        if _call_name(call) not in ("open", "io.open"):
            return False
        mode = None
        if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
            mode = call.args[1].value
        for kw in call.keywords:
            if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
                mode = kw.value.value
        return isinstance(mode, str) and any(c in mode for c in "wxa")

    class V(ast.NodeVisitor):
        def _do_func(self, node):
            writes, atomic = [], False
            stack = list(ast.iter_child_nodes(node))
            while stack:
                n = stack.pop()
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._do_func(n)
                    continue
                if isinstance(n, ast.Call):
                    name = _call_name(n)
                    if name == "os.rename":
                        out.append((n.lineno,
                                    "os.rename is not atomic over an "
                                    "existing target everywhere; use "
                                    "os.replace"))
                    if name in ("os.replace", "os.fsync"):
                        atomic = True
                    if write_mode(n):
                        writes.append(n.lineno)
                stack.extend(ast.iter_child_nodes(n))
            if writes and not atomic:
                for line in writes:
                    out.append((line,
                                "in-place write with no os.replace in this "
                                "function; write a temp sibling and "
                                "os.replace it (crash atomicity)"))

        def visit_FunctionDef(self, node):
            self._do_func(node)

        visit_AsyncFunctionDef = visit_FunctionDef

    V().visit(tree)
    return out


# ---------------------------------------------------------------- driver ----

def _waivers(src_lines) -> dict:
    """line -> (set of waived rule ids, reason) for every waiver comment."""
    out = {}
    for i, line in enumerate(src_lines, 1):
        m = WAIVER_RE.search(line)
        if m:
            ids = {p.strip() for p in m.group(1).split(",")}
            out[i] = (ids, (m.group(2) or "").strip())
    return out


def lint_file(path, root=None) -> list[Finding]:
    """All rule findings for one file, waivers applied (a waiver on the
    finding's line or the line directly above suppresses it)."""
    path = Path(path)
    rel = str(path.relative_to(root)) if root else str(path)
    src = path.read_text()
    tree = ast.parse(src, filename=str(path))
    lines = src.splitlines()
    waivers = _waivers(lines)
    findings = []
    for r in _RULES:
        if not r.applies(rel):
            continue
        for line, msg in r.check(tree, lines, rel):
            waived, reason = False, ""
            for probe in (line, line - 1):
                w = waivers.get(probe)
                if w and (r.id in w[0] or "all" in w[0]):
                    waived, reason = True, w[1]
                    break
            findings.append(Finding(rule=r.id, layer="lint",
                                    location=f"{rel}:{line}", message=msg,
                                    waived=waived, waiver_reason=reason))
    return sorted(findings, key=lambda f: f.location)


def run_lint(root, subdirs=("src", "benchmarks")) -> list[Finding]:
    """Lint every Python file under `root`'s code subdirs (tests and
    fixtures are deliberately out of scope — they assert on hazards)."""
    root = Path(root)
    findings = []
    for sub in subdirs:
        base = root / sub
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.py")):
            findings.extend(lint_file(path, root=root))
    return findings


__all__ = ["LintRule", "WAIVER_RE", "lint_file", "register_rule", "rules",
           "run_lint"]
