"""Layer 3a: the trace-only cost model (DESIGN §15).

For every step variant in the matrix (`invariants.build_variants`) this
module derives, from the traced jaxpr and the lowered (never compiled)
StableHLO:

* **collective volume** — op counts and payload bytes per collective kind
  (psum/all-gather/reduce-scatter/all-to-all/ppermute), with static scan
  trip counts multiplied in, and each site attributed to the flat bucket
  groups when its operands are bucket buffers (by `layout_marker_p`
  adjacency in its scope, or by bucket-shape match against the variant's
  `FlatLayout`).  Only *manually placed* collectives (shard_map regions)
  exist before compilation; GSPMD-inserted ones (ACCUM-NORM's
  `with_sharding_constraint` resharding) appear during SPMD partitioning
  and are invisible to a trace-only analysis — their budget entry is the
  honest zero, and the sharding-agreement check in layer 1 is what pins
  that path's layout.
* **analytic FLOPs** — 2·batch·M·N·K per `dot_general`, one flop per
  output element for elementwise compute, scan bodies × trip count, cond
  branches at their max.
* **a peak-memory watermark** — a liveness sweep over the step's pjit
  body where an input that XLA actually aliased to an output
  (`tf.aliasing_output` in the lowered text) makes that output free: a
  *dropped* donation therefore raises the watermark by exactly the
  double-allocated state it regresses, which is the class this metric
  gates.

All three are frozen in a committed machine-readable baseline
(`analysis_budget.json`).  `run_cost_checks` diffs a fresh measurement
against it — op counts exactly, byte/FLOP/peak metrics within the
per-metric tolerances the budget file itself declares — and emits
findings on any drift in EITHER direction (an improvement is a budget
update, not a free pass), plus staleness findings when the budget and the
traced matrix disagree about which variants exist.  Intentional changes
go through ``python -m repro.analysis --update-budget``.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

BUDGET_SCHEMA = 1
BUDGET_FILENAME = "analysis_budget.json"

# drift allowed per derived metric before the gate fires; op counts are
# always exact.  These are the DEFAULTS stamped into a fresh budget — the
# committed file's own `tolerances` block is what the diff actually uses,
# so loosening for a JAX upgrade is a reviewed one-line change.
DEFAULT_TOLERANCES = {
    "collective_bytes": 0.0,   # payload bytes are pure static-shape math
    "flops": 0.01,
    "peak_bytes": 0.10,        # liveness order can shift across JAX minors
}


def _aval_bytes(aval) -> int:
    size = getattr(aval, "size", None)
    dtype = getattr(aval, "dtype", None)
    if size is None or dtype is None:       # tokens etc.
        return 0
    return int(size) * dtype.itemsize


def _is_var(v) -> bool:
    # jaxpr Vars participate in dataflow; Literals don't (and may not hash)
    return getattr(v, "count", None) is not None


def _unwrap(jaxpr):
    return jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr


def collective_kind(prim_name: str) -> str | None:
    """Canonical collective kind of a primitive name, or None.  `psum2` and
    friends fold onto their base kind; pmax/pmin are all-reduces."""
    for prefix, kind in (("all_gather", "all_gather"),
                         ("reduce_scatter", "reduce_scatter"),
                         ("psum_scatter", "reduce_scatter"),
                         ("psum", "psum"),
                         ("pmax", "all_reduce"), ("pmin", "all_reduce"),
                         ("all_to_all", "all_to_all"),
                         ("ppermute", "ppermute")):
        if prim_name.startswith(prefix):
            return kind
    return None


def _eqn_subs(eqn):
    """(sub_jaxprs, trip_mult, is_cond) for one equation.  `scan` returns
    its body with the static trip count; `cond`/`switch` return every
    branch flagged so callers pick their policy (count one, diff all)."""
    p = eqn.params
    name = eqn.primitive.name
    if "branches" in p:
        return list(p["branches"]), 1, True
    if name == "scan":
        return [p["jaxpr"]], int(p.get("length", 1)), False
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = p.get(key)
        if sub is not None and (hasattr(sub, "eqns") or hasattr(sub, "jaxpr")):
            return [sub], 1, False
    subs = [s for v in p.values()
            for s in (v if isinstance(v, (list, tuple)) else (v,))
            if hasattr(s, "eqns") or hasattr(s, "jaxpr")]
    return subs, 1, False


def _axes_of(eqn) -> tuple:
    axes = eqn.params.get("axes") or eqn.params.get("axis_name") or ()
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    return tuple(str(a) for a in axes)


# ------------------------------------------------- collective profiling ----

@dataclass(frozen=True)
class CollectiveSite:
    """One collective equation in a step graph (scan-multiplied)."""
    kind: str           # canonical kind (see `collective_kind`)
    primitive: str      # raw primitive name
    count: int          # executions per step (static trip counts folded in)
    bytes: int          # payload bytes per step (output avals × count)
    axes: tuple         # mesh axis names it reduces/gathers over
    flatbuf: bool       # attributed to a flat bucket group


# data-movement ops taint flows through when relating markers to the
# collectives that move the marked buffers
_TRANSPARENT = frozenset({
    "reshape", "convert_element_type", "slice", "dynamic_slice",
    "dynamic_update_slice", "transpose", "broadcast_in_dim", "squeeze",
    "expand_dims", "concatenate", "pad", "copy", "rev",
    "repro_layout_marker",
})


def _marker_adjacency(jx):
    """Per-scope var sets: `fwd` = reachable from a marker's outputs,
    `bwd` = reaching a marker's inputs, both through transparent
    data-movement ops only (eqns are in topological order)."""
    from repro.analysis.jaxpr_check import LAYOUT_MARKER
    fwd, bwd = set(), set()
    for eqn in jx.eqns:
        if eqn.primitive.name == LAYOUT_MARKER:
            fwd.update(eqn.outvars)
            bwd.update(v for v in eqn.invars if _is_var(v))
    for eqn in jx.eqns:
        if eqn.primitive.name in _TRANSPARENT and \
                any(v in fwd for v in eqn.invars if _is_var(v)):
            fwd.update(eqn.outvars)
    for eqn in reversed(jx.eqns):
        if eqn.primitive.name in _TRANSPARENT and \
                any(v in bwd for v in eqn.outvars):
            bwd.update(v for v in eqn.invars if _is_var(v))
    return fwd, bwd


def collective_sites(jaxpr, layout=None, _mult: int = 1) -> list[CollectiveSite]:
    """Every collective eqn in the (recursively entered) graph, with scan
    trip counts multiplied in and cond branches counted once (branch
    agreement is `divergence.py`'s check).  A site is flat-bucket
    attributed when it is marker-adjacent in its scope, or when its
    operands are 1-D buffers whose sizes match `layout`'s buckets (whole
    or per-shard) — bucket buffers enter a step as plain jit inputs, so
    shape-matching catches the gathers that run before any marker eqn."""
    jx = _unwrap(jaxpr)
    fwd, bwd = _marker_adjacency(jx)
    bucket_sizes = set()
    if layout is not None:
        for n in layout.buffer_sizes:
            bucket_sizes.add(int(n))
            div = getattr(layout, "shard_divisor", 1) or 1
            if div > 1 and n % div == 0:
                bucket_sizes.add(int(n) // div)
    sites: list[CollectiveSite] = []
    for eqn in jx.eqns:
        kind = collective_kind(eqn.primitive.name)
        if kind is not None:
            payload = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            adjacent = (any(v in fwd for v in eqn.invars if _is_var(v))
                        or any(v in bwd for v in eqn.outvars))
            shaped = bucket_sizes and any(
                len(getattr(v.aval, "shape", ())) == 1
                and v.aval.shape[0] in bucket_sizes for v in eqn.outvars)
            sites.append(CollectiveSite(
                kind=kind, primitive=eqn.primitive.name, count=_mult,
                bytes=payload * _mult, axes=_axes_of(eqn),
                flatbuf=bool(adjacent or shaped)))
        subs, mult, is_cond = _eqn_subs(eqn)
        if is_cond:
            if subs:
                sites.extend(collective_sites(subs[0], layout, _mult))
        else:
            for sub in subs:
                sites.extend(collective_sites(sub, layout, _mult * mult))
    return sites


def collective_profile(jaxpr, layout=None) -> dict:
    """Aggregate `collective_sites` into the budget's per-kind shape:
    {kind: {"count": n, "bytes": b}} plus the flat-bucket-attributed
    totals."""
    per_kind: dict = {}
    fb_count = fb_bytes = 0
    for s in collective_sites(jaxpr, layout):
        e = per_kind.setdefault(s.kind, {"count": 0, "bytes": 0})
        e["count"] += s.count
        e["bytes"] += s.bytes
        if s.flatbuf:
            fb_count += s.count
            fb_bytes += s.bytes
    return {"per_kind": dict(sorted(per_kind.items())),
            "flatbuf": {"count": fb_count, "bytes": fb_bytes}}


# --------------------------------------------------------- analytic FLOPs ----

# pure data movement: zero flops regardless of output size
_ZERO_FLOP = _TRANSPARENT | frozenset({
    "iota", "stop_gradient", "device_put", "gather", "scatter",
    "bitcast_convert_type", "select_n", "split",
})


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    batch = 1
    for i in lb:
        batch *= lhs[i]
    k = 1
    for i in lc:
        k *= lhs[i]
    m = n = 1
    for i, d in enumerate(lhs):
        if i not in set(lc) | set(lb):
            m *= d
    for i, d in enumerate(rhs):
        if i not in set(rc) | set(rb):
            n *= d
    return 2 * batch * m * n * k


def flops_estimate(jaxpr) -> int:
    """Analytic FLOPs of one step: exact matmul math for `dot_general`,
    one flop per output element elsewhere, scan × static trip count, cond
    at the max over branches.  Deterministic by construction — this is a
    budget metric, not a profiler."""
    jx = _unwrap(jaxpr)
    total = 0
    for eqn in jx.eqns:
        subs, mult, is_cond = _eqn_subs(eqn)
        if subs:
            inner = [flops_estimate(s) for s in subs]
            total += max(inner) if is_cond else mult * sum(inner)
            continue
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn)
        elif name not in _ZERO_FLOP:
            total += sum(int(getattr(v.aval, "size", 0))
                         for v in eqn.outvars)
    return total


# ------------------------------------------------- peak-memory watermark ----

def _scope_peak(jx, zero_cost=frozenset()) -> int:
    """Liveness sweep over one scope: a var is resident from its defining
    eqn to its last use (scope outputs to the end); container eqns add
    their body's own peak on top of the parent's residency at that point.
    Vars in `zero_cost` (outputs covered by an accepted donation) are
    never charged — so a donation XLA dropped shows up as exactly the
    doubled state."""
    jx = _unwrap(jx)
    eqns = list(jx.eqns)
    last_use: dict = {}
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if _is_var(v):
                last_use[v] = i
    for v in jx.outvars:
        if _is_var(v):
            last_use[v] = len(eqns)
    frees: dict = {}
    for v, i in last_use.items():
        frees.setdefault(i, []).append(v)
    cur = sum(_aval_bytes(v.aval)
              for v in list(jx.invars) + list(jx.constvars))
    peak = cur
    for i, eqn in enumerate(eqns):
        subs, _, _ = _eqn_subs(eqn)
        inner = max((_scope_peak(s) for s in subs), default=0)
        out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars
                    if v in last_use and v not in zero_cost)
        cur += out_b
        peak = max(peak, cur + inner)
        for v in frees.get(i, ()):
            if v not in zero_cost:
                cur -= _aval_bytes(v.aval)
    return peak


def peak_memory(traced, arg_attrs=None) -> int:
    """Peak-residency watermark of a traced jitted step (bytes).  Operates
    on the outermost pjit body; `arg_attrs` (from
    `jaxpr_check.main_arg_attrs` of the lowering) names the inputs XLA
    actually aliased — each is greedily matched to a same-shaped scope
    output, which then costs nothing (in-place update).  Without attrs the
    watermark is the no-donation upper bound."""
    from repro.analysis.jaxpr_check import top_pjit_params
    params = top_pjit_params(traced)
    if params is None:
        return _scope_peak(traced)
    inner = _unwrap(params["jaxpr"])
    zero_cost: set = set()
    if arg_attrs:
        outs = [v for v in inner.outvars if _is_var(v)]
        taken: set = set()
        for a in arg_attrs:
            if not a.aliased or a.index >= len(inner.invars):
                continue
            want = inner.invars[a.index].aval
            for v in outs:
                if v in taken or v in zero_cost:
                    continue
                if (getattr(v.aval, "shape", None) == want.shape
                        and getattr(v.aval, "dtype", None) == want.dtype):
                    zero_cost.add(v)
                    taken.add(v)
                    break
    return _scope_peak(inner, zero_cost=frozenset(zero_cost))


# -------------------------------------------------------- variant metrics ----

def variant_cost(v, mesh=None) -> dict:
    """All layer-3 metrics for one `StepVariant` (trace + lower, never
    compile)."""
    from repro.analysis.jaxpr_check import main_arg_attrs, trace
    from repro.compat import set_mesh
    if mesh is None:
        from repro.analysis.invariants import _smoke_parts
        _, _, mesh = _smoke_parts()
    with set_mesh(mesh):
        traced = trace(v.fn, *v.args)
        lowered_text = v.fn.lower(*v.args).as_text()
    attrs = main_arg_attrs(lowered_text)
    layout = getattr(v, "layout", None)
    prof = collective_profile(traced, layout)
    return {
        "collectives": prof["per_kind"],
        "flatbuf": prof["flatbuf"],
        "flops": flops_estimate(traced),
        "peak_bytes": peak_memory(traced, attrs),
        "donated_aliased": sum(1 for a in attrs if a.aliased),
    }


def measure_variants(variants=None) -> dict:
    """{variant name: metrics} for the whole matrix (or a prebuilt
    subset)."""
    from repro.analysis.invariants import _smoke_parts, build_variants
    if variants is None:
        variants = build_variants()
    _, _, mesh = _smoke_parts()
    return {v.name: variant_cost(v, mesh) for v in variants}


# ----------------------------------------------------------------- budget ----

def load_budget(path) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def write_budget(path, measured: dict) -> dict:
    """Freeze `measured` as the committed baseline (atomic replace).  The
    topology is recorded because collective structure is mesh-dependent:
    a budget measured at a different device count is stale, not wrong."""
    import jax
    budget = {
        "schema": BUDGET_SCHEMA,
        "topology": {"device_count": jax.device_count(),
                     "backend": jax.default_backend()},
        "tolerances": dict(DEFAULT_TOLERANCES),
        "variants": {k: measured[k] for k in sorted(measured)},
    }
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(budget, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return budget


def _rel_drift(got: float, want: float) -> float:
    return abs(got - want) / max(abs(want), 1.0)


def budget_diff(measured: dict, budget: dict) -> list:
    """Findings for every way `measured` disagrees with `budget`:
    staleness (variant sets / topology out of sync), exact op-count
    drift, and relative metric drift beyond the budget's own
    tolerances.  Symmetric — regressions AND improvements both require
    an explicit `--update-budget`."""
    import jax
    from repro.analysis.findings import Finding

    def f(rule, loc, msg):
        return Finding(rule=rule, layer="cost", location=loc, message=msg)

    findings = []
    tol = {**DEFAULT_TOLERANCES, **(budget.get("tolerances") or {})}
    topo = budget.get("topology") or {}
    if topo.get("device_count") not in (None, jax.device_count()):
        findings.append(f(
            "budget-stale", BUDGET_FILENAME,
            f"budget was frozen at device_count="
            f"{topo.get('device_count')} but this run has "
            f"{jax.device_count()} — collective structure is "
            f"mesh-dependent; regenerate with --update-budget on the CI "
            f"topology"))
        return findings
    b_vars = budget.get("variants") or {}
    for name in sorted(set(measured) - set(b_vars)):
        findings.append(f(
            "budget-stale", name,
            "variant is in the traced matrix but missing from "
            f"{BUDGET_FILENAME}; run --update-budget"))
    for name in sorted(set(b_vars) - set(measured)):
        findings.append(f(
            "budget-stale", name,
            f"budget entry matches no variant in the traced matrix "
            f"(removed or renamed?); run --update-budget"))
    for name in sorted(set(measured) & set(b_vars)):
        got, want = measured[name], b_vars[name]
        gk, wk = got["collectives"], want.get("collectives", {})
        for kind in sorted(set(gk) | set(wk)):
            g = gk.get(kind, {"count": 0, "bytes": 0})
            w = wk.get(kind, {"count": 0, "bytes": 0})
            if g["count"] != w["count"]:
                findings.append(f(
                    "cost-collectives", name,
                    f"{kind} op count {g['count']} != budget "
                    f"{w['count']} — a collective was added or removed"))
            elif _rel_drift(g["bytes"], w["bytes"]) > tol["collective_bytes"]:
                findings.append(f(
                    "cost-collectives", name,
                    f"{kind} payload {g['bytes']}B drifted from budget "
                    f"{w['bytes']}B (tol {tol['collective_bytes']:.0%})"))
        gf, wf = got["flatbuf"], want.get("flatbuf", {"count": 0, "bytes": 0})
        if gf["count"] != wf["count"]:
            findings.append(f(
                "cost-collectives", name,
                f"flat-bucket-attributed collective count {gf['count']} "
                f"!= budget {wf['count']}"))
        if _rel_drift(got["flops"], want.get("flops", 0)) > tol["flops"]:
            findings.append(f(
                "cost-flops", name,
                f"analytic FLOPs {got['flops']:.4g} drifted from budget "
                f"{want.get('flops', 0):.4g} (tol {tol['flops']:.0%})"))
        if _rel_drift(got["peak_bytes"],
                      want.get("peak_bytes", 0)) > tol["peak_bytes"]:
            findings.append(f(
                "cost-peak-memory", name,
                f"peak-memory watermark {got['peak_bytes']}B drifted from "
                f"budget {want.get('peak_bytes', 0)}B (tol "
                f"{tol['peak_bytes']:.0%}) — check donation aliasing and "
                f"buffer lifetimes"))
        if got["donated_aliased"] < want.get("donated_aliased",
                                             got["donated_aliased"]):
            findings.append(f(
                "cost-peak-memory", name,
                f"{got['donated_aliased']} inputs aliased vs budget "
                f"{want['donated_aliased']} — a donation was dropped"))
    return findings


def run_cost_checks(budget_path, variants=None,
                    update: bool = False) -> tuple[list, dict]:
    """The layer-3a entry point: measure the matrix, then diff against
    (or, with `update`, rewrite) the committed budget.  Returns
    (findings, checked) where `checked["cost"]` carries the full
    per-variant metrics so the CI report always publishes comm bytes,
    FLOPs, and peak memory for every combo."""
    from repro.analysis.findings import Finding
    measured = measure_variants(variants)
    checked = {"budget": str(budget_path), "metrics": measured}
    if update:
        write_budget(budget_path, measured)
        checked["budget_updated"] = True
        return [], checked
    budget = load_budget(budget_path)
    if budget is None:
        return [Finding(
            rule="budget-stale", layer="cost", location=str(budget_path),
            message="no committed cost budget; run "
                    "`python -m repro.analysis --update-budget` and commit "
                    f"{BUDGET_FILENAME}")], checked
    return budget_diff(measured, budget), checked


__all__ = ["BUDGET_FILENAME", "CollectiveSite", "DEFAULT_TOLERANCES",
           "budget_diff", "collective_kind", "collective_profile",
           "collective_sites", "flops_estimate", "load_budget",
           "measure_variants", "peak_memory", "run_cost_checks",
           "variant_cost", "write_budget"]
