"""Layer 3b: the SPMD-divergence lint (DESIGN §15).

SPMD programs deadlock (or silently desync) when ranks disagree about
which collective comes next.  Rank-dependent *values* are what collectives
are for; rank-dependent collective ORDER is always a bug.  Before the
elastic-membership work makes step graphs a function of fleet state, this
module pins the two statically checkable halves of that contract:

* **emission-order determinism** — trace every step variant TWICE,
  independently, and require identical ordered collective signatures
  (kind, mesh axes, payload shape, scope path).  A builder that iterates
  an unordered container, or branches on host state (process index, pid,
  wall clock), emits different graphs on different ranks — and also on
  two traces within one process, which is what makes the hazard visible
  to a single-host CI run.
* **cond-branch agreement** — both branches of every traced `cond` /
  `switch` must contain the same collective sequence: a collective under
  a data-dependent branch runs on the ranks whose predicate was true and
  deadlocks the rest.

The third half is lexical and lives in `lint.py` (`host-divergence`):
host-identity reads (`jax.process_index`, `os.getpid`, hostname) inside
traced-scope source files.
"""

from __future__ import annotations

from repro.analysis.costmodel import _axes_of, _eqn_subs, _unwrap, collective_kind
from repro.analysis.findings import Finding


def collective_signature(jaxpr, _path: str = "") -> tuple:
    """Ordered tuple of collective events in the traced graph —
    `(scope_path, primitive, axes, output shapes)` per site, in emission
    order, cond branches included under distinct paths (branch agreement
    is checked separately; for ordering purposes every branch is part of
    the signature)."""
    jx = _unwrap(jaxpr)
    sig = []
    for i, eqn in enumerate(jx.eqns):
        name = eqn.primitive.name
        if collective_kind(name) is not None:
            shapes = tuple(tuple(getattr(v.aval, "shape", ()))
                           for v in eqn.outvars)
            sig.append((_path, name, _axes_of(eqn), shapes))
        subs, _, is_cond = _eqn_subs(eqn)
        for bi, sub in enumerate(subs):
            tag = f"{_path}/{name}.{i}" + (f".b{bi}" if is_cond else "")
            sig.extend(collective_signature(sub, tag))
    return tuple(sig)


def branch_collective_mismatches(jaxpr) -> list[tuple[str, list]]:
    """Every `cond`/`switch` eqn whose branches disagree on their
    collective sequence: [(eqn label, per-branch signatures)]."""
    out = []

    def walk(jx, path):
        jx = _unwrap(jx)
        for i, eqn in enumerate(jx.eqns):
            subs, _, is_cond = _eqn_subs(eqn)
            if is_cond and len(subs) > 1:
                sigs = [tuple((n, a, s) for _, n, a, s in
                              collective_signature(b)) for b in subs]
                if len(set(sigs)) > 1:
                    out.append((f"{path}/{eqn.primitive.name}.{i}", sigs))
            for sub in subs:
                walk(sub, f"{path}/{eqn.primitive.name}.{i}")

    walk(jaxpr, "")
    return out


def check_fn_divergence(fn, args, location: str, mesh=None) -> list[Finding]:
    """Both divergence checks on one traceable step: trace twice, compare
    ordered collective signatures, then check cond-branch agreement on the
    first trace.  The second trace must be genuinely fresh: a jitted step
    caches its traced body on the pjit AND in jax's global trace caches
    (shard_map/custom_vjp bodies are keyed on the Python function object),
    either of which would hide a builder whose emission order flips
    between calls — so ALL of jax's caches are dropped between the two
    (later jit calls in this process simply retrace/recompile; this
    checker runs in the one-shot analysis CLI where that costs nothing)."""
    from repro.analysis.jaxpr_check import trace
    from repro.compat import set_mesh
    import contextlib
    import jax
    ctx = set_mesh(mesh) if mesh is not None else contextlib.nullcontext()
    with ctx:
        t1 = trace(fn, *args)
        jax.clear_caches()
        t2 = trace(fn, *args)
    findings = []
    s1, s2 = collective_signature(t1), collective_signature(t2)
    if s1 != s2:
        diverge_at = next((i for i, (a, b) in enumerate(zip(s1, s2))
                           if a != b), min(len(s1), len(s2)))
        findings.append(Finding(
            rule="divergence-order", layer="cost", location=location,
            message=f"two traces of the same step emit different collective "
                    f"sequences (lengths {len(s1)} vs {len(s2)}, first "
                    f"divergence at site {diverge_at}) — the builder's "
                    f"emission order is host-state-dependent, so ranks "
                    f"would build different programs and deadlock"))
    for label, sigs in branch_collective_mismatches(t1):
        findings.append(Finding(
            rule="divergence-cond", layer="cost", location=location,
            message=f"cond branches at {label} contain different collective "
                    f"sequences {[len(s) for s in sigs]} — ranks whose "
                    f"predicate differs would disagree on the next "
                    f"collective and deadlock"))
    return findings


def run_divergence_checks(variants=None) -> tuple[list[Finding], dict]:
    """Layer-3b over the whole step matrix (or a prebuilt subset)."""
    from repro.analysis.invariants import _smoke_parts, build_variants
    if variants is None:
        variants = build_variants()
    _, _, mesh = _smoke_parts()
    findings = []
    for v in variants:
        findings.extend(check_fn_divergence(v.fn, v.args, v.name, mesh))
    checked = {"variants": [v.name for v in variants],
               "checks": ["divergence-order", "divergence-cond"]}
    return findings, checked


__all__ = ["branch_collective_mismatches", "check_fn_divergence",
           "collective_signature", "run_divergence_checks"]
