"""Shared finding/report vocabulary for both analysis layers (DESIGN §13).

A `Finding` is one violated invariant or lint rule, locatable (file:line for
the AST lint, step-variant name for the jaxpr checker) and machine-readable
(`as_dict` feeds the CLI's JSON report).  Waived lint findings are kept in
the report — a waiver documents a deliberate exception, it doesn't erase
the event — but never fail the gate.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class Finding:
    rule: str                       # e.g. "pack-count", "hash-seed"
    layer: str                      # "jaxpr" | "lint" | "cost"
    location: str                   # "path:line" or a step-variant name
    message: str
    waived: bool = False
    waiver_reason: str = ""

    def as_dict(self) -> dict:
        return asdict(self)

    def render(self) -> str:
        tag = " [waived]" if self.waived else ""
        return f"{self.location}: {self.rule}{tag}: {self.message}"


def active(findings) -> list[Finding]:
    """The findings that fail the gate (waivers excluded)."""
    return [f for f in findings if not f.waived]


def report_dict(findings, *, checked: dict | None = None) -> dict:
    """The machine-readable report: every finding (waived ones flagged),
    plus a `checked` section recording what the run actually covered so a
    clean report is distinguishable from a run that checked nothing."""
    return {
        "findings": [f.as_dict() for f in findings],
        "active": len(active(findings)),
        "waived": sum(1 for f in findings if f.waived),
        "checked": checked or {},
    }


def render_report(findings, *, checked: dict | None = None,
                  as_json: bool = False) -> str:
    if as_json:
        return json.dumps(report_dict(findings, checked=checked), indent=2,
                          sort_keys=True)
    lines = [f.render() for f in findings]
    metrics = ((checked or {}).get("cost") or {}).get("metrics") or {}
    for name in sorted(metrics):
        m = metrics[name]
        comm = sum(e["bytes"] for e in m["collectives"].values())
        nops = sum(e["count"] for e in m["collectives"].values())
        lines.append(
            f"cost {name}: comm={comm}B/{nops}op "
            f"(flatbuf {m['flatbuf']['bytes']}B) flops={m['flops']} "
            f"peak={m['peak_bytes']}B aliased={m['donated_aliased']}")
    act = active(findings)
    lines.append(
        f"{len(act)} finding(s), {len(findings) - len(act)} waived")
    return "\n".join(lines)


__all__ = ["Finding", "active", "report_dict", "render_report"]
