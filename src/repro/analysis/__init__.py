"""repro.analysis — static invariant checker, cost-model budget gate, and
determinism/perf lint (DESIGN §13, §15).

Three layers, all purely static (trace/lower — never execute):

* **jaxpr layer** (`jaxpr_check`, `invariants`): trace every step variant
  in the stats×params residency matrix and assert the step-graph
  invariants: exact pack/unflatten/adjoint marker-eqn counts, donation
  actually aliased in the lowered HLO, bucket shardings matching
  `sharding.flat_buffer_specs`, no host callbacks in the hot path, and
  off-ladder batch shapes rejected before anything traces.
* **cost layer** (`costmodel`, `divergence`): per-variant collective
  volume (bytes + op counts per kind, attributed to flat bucket groups),
  analytic FLOPs, and a peak-memory watermark from a liveness sweep with
  donation credit — diffed against the committed `analysis_budget.json`
  baseline with per-metric tolerances; plus the SPMD-divergence lint
  (rank-independent collective order, cond branches agreeing on their
  collective sequence).
* **lint layer** (`lint`): AST rules over the repo's own source encoding
  its regression history (hash-seeded cache keys, wall-clock in traced
  code, host-identity reads feeding traced code, bare ``interpret=True``,
  set-order iteration, unfenced benchmark timing, non-atomic durable
  writes), with inline ``# repro: allow(<rule>) — <reason>`` waivers.

CLI: ``python -m repro.analysis [--strict] [--json] [--update-budget]``
runs all three and emits a machine-readable report; CI gates every PR on
zero unwaived findings.
"""

from repro.analysis.costmodel import (
    DEFAULT_TOLERANCES, CollectiveSite, budget_diff, collective_profile,
    collective_sites, flops_estimate, load_budget, measure_variants,
    peak_memory, run_cost_checks, variant_cost, write_budget)
from repro.analysis.divergence import (
    branch_collective_mismatches, check_fn_divergence, collective_signature,
    run_divergence_checks)
from repro.analysis.findings import Finding, active, render_report, report_dict
from repro.analysis.invariants import (
    EXPECTED_LAYOUT_COUNTS, LayoutCounts, build_variants,
    check_ladder_rejection, check_variant, run_invariant_checks)
from repro.analysis.jaxpr_check import (
    count_layout_ops, donation_effective, find_host_eqns, in_specs,
    iter_eqns, main_arg_attrs, top_pjit_params, trace)
from repro.analysis.lint import lint_file, register_rule, rules, run_lint

__all__ = [
    "CollectiveSite", "DEFAULT_TOLERANCES", "EXPECTED_LAYOUT_COUNTS",
    "Finding", "LayoutCounts", "active", "branch_collective_mismatches",
    "budget_diff", "build_variants", "check_fn_divergence",
    "check_ladder_rejection", "check_variant", "collective_profile",
    "collective_signature", "collective_sites", "count_layout_ops",
    "donation_effective", "find_host_eqns", "flops_estimate", "in_specs",
    "iter_eqns", "lint_file", "load_budget", "main_arg_attrs",
    "measure_variants", "peak_memory", "register_rule", "render_report",
    "report_dict", "rules", "run_cost_checks", "run_divergence_checks",
    "run_invariant_checks", "run_lint", "top_pjit_params", "trace",
    "variant_cost", "write_budget",
]
