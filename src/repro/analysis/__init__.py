"""repro.analysis — static invariant checker + determinism/perf lint
(DESIGN §13).

Two layers, both purely static:

* **jaxpr layer** (`jaxpr_check`, `invariants`): trace — never execute —
  every step variant in the stats×params residency matrix and assert the
  step-graph invariants: exact pack/unflatten/adjoint marker-eqn counts,
  donation actually aliased in the lowered HLO, bucket shardings matching
  `sharding.flat_buffer_specs`, no host callbacks in the hot path, and
  off-ladder batch shapes rejected before anything traces.
* **lint layer** (`lint`): AST rules over the repo's own source encoding
  its regression history (hash-seeded cache keys, wall-clock in traced
  code, bare ``interpret=True``, set-order iteration, unfenced benchmark
  timing, non-atomic durable writes), with inline
  ``# repro: allow(<rule>) — <reason>`` waivers.

CLI: ``python -m repro.analysis [--strict] [--json]`` runs both and emits
a machine-readable report; CI gates every PR on zero unwaived findings.
"""

from repro.analysis.findings import Finding, active, render_report, report_dict
from repro.analysis.invariants import (
    EXPECTED_LAYOUT_COUNTS, LayoutCounts, build_variants,
    check_ladder_rejection, check_variant, run_invariant_checks)
from repro.analysis.jaxpr_check import (
    count_layout_ops, donation_effective, find_host_eqns, in_specs,
    iter_eqns, main_arg_attrs, top_pjit_params, trace)
from repro.analysis.lint import lint_file, register_rule, rules, run_lint

__all__ = [
    "EXPECTED_LAYOUT_COUNTS", "Finding", "LayoutCounts", "active",
    "build_variants", "check_ladder_rejection", "check_variant",
    "count_layout_ops", "donation_effective", "find_host_eqns", "in_specs",
    "iter_eqns", "lint_file", "main_arg_attrs", "register_rule",
    "render_report", "report_dict", "rules", "run_invariant_checks",
    "run_lint", "top_pjit_params", "trace",
]
