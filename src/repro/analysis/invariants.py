"""The step-graph invariant catalog + trace-only matrix checker (DESIGN §13).

`run_invariant_checks()` builds every step variant the repo ships — the
stats×params residency matrix over FSDP-Norm, ACCUM-NORM, and local-SGD,
plus the serving slot-decode step — at smoke scale, TRACES each one (never
executes, never compiles to a loaded executable), and statically asserts:

* **layout op counts** (`EXPECTED_LAYOUT_COUNTS`): the exact number of
  pack / unflatten / adjoint-pack marker eqns in the step graph.  Frozen
  per residency combo; a drift in `pack` is the PR 3 double-pack class, a
  drift in `adjoint` means a gradient is being transposed more than once.
* **donation effectiveness**: every input the step declares donated is
  actually aliased to an output in the lowered HLO (`tf.aliasing_output`).
  A donation XLA silently drops doubles the step's parameter/optimizer
  memory — invisible until OOM at scale.
* **sharding agreement**: the traced pjit's input shardings equal the
  builder's declared (p_specs, o_specs), and flat bucket groups carry
  exactly `sharding.flat_buffer_specs` (data-sharded moments, DESIGN §9).
* **no host exits**: no callback / debug_print / infeed / interpreted
  Pallas eqn anywhere in the hot-path graph.
* **ladder hygiene**: every traced batch signature sits on its ladder, and
  an off-ladder batch is rejected by `BucketedEngine.get_step` with
  `LadderShapeError` BEFORE anything traces (`stats.compiles == 0`).

Run it via ``python -m repro.analysis`` (CI's static-analysis gate) or
call the functions directly from tests.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_check import (
    count_layout_ops, donation_effective, find_host_eqns, in_specs, trace)


@dataclass(frozen=True)
class LayoutCounts:
    """Frozen marker-eqn counts for one step graph (see the catalog)."""
    packs: int
    unflattens: int
    adjoints: int


# The invariant catalog: layout op counts per (step, stats_impl, params_impl)
# residency combo.  The `packs` column is the historical pack-count
# regression matrix (tests/test_flatbuf.py); `unflattens`/`adjoints` are the
# jaxpr-visible counts the removed Python-call proxy could never see.
EXPECTED_LAYOUT_COUNTS = {
    # FSDP-Norm, flat stats over tree params: packs g_j, mean g, and the
    # params (3) — the PR 3 regression packed g TWICE here (packs=4); one
    # unflatten returns the updated params to tree form.
    ("fsdp_norm", "flat", "tree"): LayoutCounts(3, 1, 0),
    # ACCUM-NORM, flat stats over tree params: packs mean g + params (2),
    # one unflatten back out.
    ("accum_norm", "flat", "tree"): LayoutCounts(2, 1, 0),
    # flat-RESIDENT params (DESIGN §10): ZERO host-level packs; exactly the
    # `unflatten_for_grad` custom-vjp pair — ONE unflatten (the primal view
    # the loss consumes; accumulation scans trace their body once, so M/H
    # never multiply it) and ONE adjoint pack (the gradient transposed into
    # buffers exactly once).
    ("fsdp_norm", "flat", "flat"): LayoutCounts(0, 1, 1),
    ("accum_norm", "flat", "flat"): LayoutCounts(0, 1, 1),
    # tree-oracle tail over flat-resident params: the custom-vjp pair, plus
    # oracle handoffs — ACCUM-NORM unflattens pb + accumulated g for the
    # tree AdamW (3 total with the primal); FSDP-Norm also unflattens
    # g_j + g for the tree variance oracle (5); the ONE pack is the updated
    # tree re-entering residency.
    ("fsdp_norm", "tree", "flat"): LayoutCounts(1, 5, 1),
    ("accum_norm", "tree", "flat"): LayoutCounts(1, 3, 1),
    # pure tree paths: the layout is never entered.
    ("fsdp_norm", "tree", "tree"): LayoutCounts(0, 0, 0),
    ("accum_norm", "tree", "tree"): LayoutCounts(0, 0, 0),
    # local-SGD rounds: flat stats pack the divergence trees Δ_j and Δ (2,
    # via worker_variance_stats_flat); the flat-resident round is buffer
    # arithmetic end-to-end — just the custom-vjp pair from the scanned
    # local step (traced once regardless of H).
    ("local_sgd", "tree", "tree"): LayoutCounts(0, 0, 0),
    ("local_sgd", "flat", "tree"): LayoutCounts(2, 0, 0),
    ("local_sgd", "flat", "flat"): LayoutCounts(0, 1, 1),
    # accumulation-free M=1 sub-steps (DESIGN §14): the train loop slices
    # one microbatch per optimizer step, so the engine sees (1, J·mb)
    # leading dims — same step builders, same custom-vjp pair (the scan
    # body is traced once regardless of M, so M=1 changes nothing the
    # layout budget can see; what this guards is that it STAYS that way,
    # since the accum-free regime was untraced before this entry).
    ("fsdp_norm_m1", "flat", "flat"): LayoutCounts(0, 1, 1),
    ("accum_norm_m1", "flat", "flat"): LayoutCounts(0, 1, 1),
    # serving decode: the KV cache is resident, nothing enters a layout.
    ("serve_decode", "-", "-"): LayoutCounts(0, 0, 0),
}


@dataclass
class StepVariant:
    """One traced-step check target (built by `build_variants`)."""
    name: str
    fn: object                  # the jitted step
    args: tuple                 # abstract operands (ShapeDtypeStructs)
    expected: LayoutCounts
    # expected PartitionSpec per flat input of the (params, opt/cache)
    # prefix, as the builder declared them
    spec_prefix: list
    # (group label, declared specs, required specs) triples for flat bucket
    # groups that must match sharding.flat_buffer_specs
    flat_groups: list
    # the builder's FlatLayout (None on tree paths) — layer 3 attributes
    # collectives to bucket groups by matching operand sizes against it
    layout: object = None


# ------------------------------------------------------- variant builders ----

_SMOKE_CACHE = []


def _smoke_parts():
    """One smoke-scale (config, model, mesh) per process — every variant
    and every `check_variant` call shares it."""
    if not _SMOKE_CACHE:
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.launch.mesh import make_host_mesh
        cfg = get_smoke_config("llama3.2-1b")
        _SMOKE_CACHE.append((cfg, build_model(cfg),
                             make_host_mesh(data=1, model=1)))
    return _SMOKE_CACHE[0]


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _spec_leaves(tree):
    return jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P))


def build_variants(combos=None) -> list[StepVariant]:
    """Every step variant in the matrix, at smoke scale, fully abstract.

    `combos` optionally restricts to a subset of
    `EXPECTED_LAYOUT_COUNTS` keys (tests use this to keep one check
    fast)."""
    from repro.compat import set_mesh
    from repro.core.schedule import BatchPlan, accum_free_plan
    from repro.data.pipeline import MarkovTokens, make_batch
    from repro.distributed.local_step import make_local_sgd_step
    from repro.distributed.serve_step import make_slot_decode_step
    from repro.distributed.sharding import flat_buffer_specs
    from repro.distributed.train_step import (
        make_accum_norm_step, make_fsdp_norm_step)
    from repro.launch.mesh import data_axes
    from repro.optim.adamw import (
        AdamWConfig, init_adamw, init_adamw_flat)

    cfg, model, mesh = _smoke_parts()
    daxes = data_axes(mesh)
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    plan = BatchPlan(global_batch=4, micro_batch=2, accum_steps=2, workers=1)
    batch = _abstract(jax.tree.map(jnp.asarray, make_batch(src, 0, plan, 16)))
    # the PR 9 accumulation-free regime: the SAME builders stepped at the
    # M=1 sub-plan (leading dims (1, J·mb)), exactly what the train loop
    # slices per optimizer step when `accum_free` engages
    sub_plan, _ = accum_free_plan(plan)
    batch_m1 = _abstract(jax.tree.map(jnp.asarray,
                                      make_batch(src, 0, sub_plan, 16)))
    params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    wanted = set(combos) if combos is not None else None
    makers = {"fsdp_norm": make_fsdp_norm_step,
              "accum_norm": make_accum_norm_step,
              "local_sgd": make_local_sgd_step}
    variants = []

    def add_train(step_impl, stats_impl, params_impl):
        key = (step_impl, stats_impl, params_impl)
        if wanted is not None and key not in wanted:
            return
        accum_free = step_impl.endswith("_m1")
        base_impl = step_impl[:-3] if accum_free else step_impl
        wrap, p_specs, o_specs = makers[base_impl](
            model, AdamWConfig(), mesh, stats_impl=stats_impl,
            params_impl=params_impl, params_like=params_like)
        layout = wrap.flat_layout
        # optimizer residency: the train steps key it on stats_impl (the
        # flat tail owns the moments), the local round on params_impl (the
        # tree round always runs the tree AdamW, even with flat stats)
        opt_flat = (params_impl if step_impl == "local_sgd"
                    else stats_impl) == "flat"
        opt = jax.eval_shape(
            (lambda p: init_adamw_flat(p, layout=layout))
            if opt_flat else init_adamw, params_like)
        p_in = (tuple(jax.ShapeDtypeStruct((n,), jnp.float32)
                      for n in layout.buffer_sizes)
                if params_impl == "flat" else params_like)
        if step_impl == "local_sgd":
            # local rounds take (H, B, ...) batches: reuse the (M, B) batch
            # as H=accum_steps local steps — same leading-dims contract
            b_in = batch
        else:
            b_in = batch_m1 if accum_free else batch
        with set_mesh(mesh):
            fn = wrap(b_in)
        flat_groups = []
        if layout is not None:
            # local-SGD replicas are whole per-worker copies (no data-axis
            # shard), the train steps shard buckets over the data axes
            axes = () if step_impl == "local_sgd" else daxes
            required = flat_buffer_specs(layout.num_buffers, axes)
            if opt_flat:
                flat_groups += [("opt.m", tuple(o_specs["m"]), required),
                                ("opt.v", tuple(o_specs["v"]), required)]
            if params_impl == "flat":
                flat_groups += [("params", tuple(p_specs), required)]
        variants.append(StepVariant(
            name="/".join(key), fn=fn,
            args=(p_in, opt, b_in, jax.ShapeDtypeStruct((), jnp.float32)),
            expected=EXPECTED_LAYOUT_COUNTS[key],
            spec_prefix=_spec_leaves((p_specs, o_specs)),
            flat_groups=flat_groups, layout=layout))

    for step_impl in ("fsdp_norm", "accum_norm"):
        for stats_impl in ("tree", "flat"):
            for params_impl in ("tree", "flat"):
                add_train(step_impl, stats_impl, params_impl)
    for step_impl in ("fsdp_norm_m1", "accum_norm_m1"):
        add_train(step_impl, "flat", "flat")
    for stats_impl, params_impl in (("tree", "tree"), ("flat", "tree"),
                                    ("flat", "flat")):
        add_train("local_sgd", stats_impl, params_impl)

    if wanted is None or ("serve_decode", "-", "-") in wanted:
        wrap, p_specs, cache_specs = make_slot_decode_step(
            model, mesh, max_slots=4, params_like=params_like)
        kv_like = jax.eval_shape(lambda: model.init_cache(4, 32))
        with set_mesh(mesh):
            fn = wrap(2, kv_like)
        tok = jax.ShapeDtypeStruct((2,), jnp.int32)
        variants.append(StepVariant(
            name="serve_decode/rung2", fn=fn,
            args=(params_like, kv_like, tok, tok),
            expected=EXPECTED_LAYOUT_COUNTS[("serve_decode", "-", "-")],
            spec_prefix=_spec_leaves((p_specs, cache_specs(kv_like))),
            flat_groups=[]))
    return variants


# --------------------------------------------------------------- checking ----

def check_variant(v: StepVariant) -> list[Finding]:
    """All invariant findings for one traced step variant (trace-only)."""
    from repro.compat import set_mesh
    _, _, mesh = _smoke_parts()
    findings = []

    def bad(rule, msg):
        findings.append(Finding(rule=rule, layer="jaxpr", location=v.name,
                                message=msg))

    with set_mesh(mesh):
        traced = trace(v.fn, *v.args)
        got = count_layout_ops(traced)
        counts = LayoutCounts(packs=len(got["pack"]),
                              unflattens=len(got["unflatten"]),
                              adjoints=len(got["adjoint"]))
        if counts != v.expected:
            bad("pack-count",
                f"layout op counts {counts} != expected {v.expected} "
                f"(pack leaf counts: {got['pack']})")

        host = find_host_eqns(traced)
        if host:
            bad("host-callback",
                f"host-exiting eqns in the step graph: {sorted(set(host))}")

        specs = in_specs(traced)
        if specs is None:
            bad("sharding", "no pjit eqn in the traced step (jit missing?)")
        else:
            prefix = specs[:len(v.spec_prefix)]
            for i, (got_s, want_s) in enumerate(zip(prefix, v.spec_prefix)):
                if got_s != want_s:
                    bad("sharding",
                        f"input {i}: traced sharding {got_s} != declared "
                        f"{want_s}")
        for label, declared, required in v.flat_groups:
            if tuple(declared) != tuple(required):
                bad("sharding",
                    f"{label} bucket specs {declared} != "
                    f"flat_buffer_specs {required}")

        attrs, dead = donation_effective(v.fn, v.args)
        if dead:
            bad("donation",
                f"donated inputs {dead} were NOT aliased by XLA (of "
                f"{len(attrs)} args) — the donation silently does nothing "
                f"and the buffers are double-allocated")
    return findings


def check_ladder_rejection() -> list[Finding]:
    """An off-ladder batch must raise `LadderShapeError` from
    `BucketedEngine.get_step` BEFORE anything traces: zero fresh lowerings,
    zero cache entries (satellite: the silent-quantize fix)."""
    from repro.core.schedule import LadderShapeError, parse_ladder
    from repro.distributed.engine import BucketedEngine
    findings = []
    ladder = parse_ladder("2:1,2:2", workers=1)
    calls = []
    engine = BucketedEngine(lambda bl: calls.append(bl), ladder)
    off = {"tokens": jax.ShapeDtypeStruct((3, 2, 16), jnp.int32),
           "labels": jax.ShapeDtypeStruct((3, 2, 16), jnp.int32)}
    try:
        engine.get_step(off)
    except LadderShapeError:
        pass
    else:
        findings.append(Finding(
            rule="ladder-reject", layer="jaxpr", location="engine.get_step",
            message="off-ladder batch (M=3) was NOT rejected"))
    if calls or engine.stats.compiles:
        findings.append(Finding(
            rule="ladder-reject", layer="jaxpr", location="engine.get_step",
            message=f"off-ladder batch reached the build path "
                    f"({len(calls)} builds, {engine.stats.compiles} "
                    f"compiles) — rejection must cost zero fresh lowerings"))
    return findings


def run_invariant_checks(combos=None, variants=None) -> tuple[list[Finding], dict]:
    """The full trace-only matrix check.  Returns (findings, checked) where
    `checked` records coverage for the report.  Pass prebuilt `variants`
    to share one matrix build with the layer-3 checks (the CLI does)."""
    if variants is None:
        variants = build_variants(combos)
    findings = []
    for v in variants:
        findings.extend(check_variant(v))
    findings.extend(check_ladder_rejection())
    checked = {
        "variants": [v.name for v in variants],
        "invariants": ["pack-count", "donation", "sharding",
                       "host-callback", "ladder-reject"],
    }
    return findings, checked


__all__ = ["EXPECTED_LAYOUT_COUNTS", "LayoutCounts", "StepVariant",
           "build_variants", "check_ladder_rejection", "check_variant",
           "run_invariant_checks"]
