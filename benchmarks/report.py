"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts in experiments/dryrun/.

    PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

ARCH_ORDER = [
    "dbrx-132b", "phi3-mini-3.8b", "whisper-base", "deepseek-v2-236b",
    "recurrentgemma-9b", "internvl2-1b", "gemma2-27b", "nemotron-4-15b",
    "mamba2-370m", "llama3.2-1b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def _fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(dir_):
    recs = {}
    for path in glob.glob(os.path.join(dir_, "*.json")):
        d = json.load(open(path))
        key = (d["arch"], d["shape"], d["mesh"], d.get("step_impl", ""))
        recs[key] = d
    return recs


def dryrun_table(recs, mesh):
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | compile | args GiB/dev | temp GiB/dev | "
          "flops/dev | AR | AG | RS | A2A | CP |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for key, d in recs.items():
                if key[0] == arch and key[1] == shape and key[2] == mesh \
                        and "accum" not in key[3]:
                    c = d["collectives"]
                    print(f"| {arch} | {shape} | {d['compile_s']}s "
                          f"| {_fmt_bytes(d['memory']['argument_size_in_bytes'])} "
                          f"| {_fmt_bytes(d['memory']['temp_size_in_bytes'])} "
                          f"| {d['roofline']['flops']:.3g} "
                          f"| {c['all-reduce']['count']} "
                          f"| {c['all-gather']['count']} "
                          f"| {c['reduce-scatter']['count']} "
                          f"| {c['all-to-all']['count']} "
                          f"| {c['collective-permute']['count']} |")


def roofline_table(recs):
    print("\n| arch | shape | compute | memory | collective | bottleneck "
          "| MODEL_FLOPS/dev | useful |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for key, d in recs.items():
                if key[0] == arch and key[1] == shape and key[2] == "16x16" \
                        and "accum" not in key[3]:
                    rl = d["roofline"]
                    print(f"| {arch} | {shape} | {_fmt_s(rl['compute_s'])} "
                          f"| {_fmt_s(rl['memory_s'])} "
                          f"| {_fmt_s(rl['collective_s'])} "
                          f"| **{rl['bottleneck']}** "
                          f"| {rl['model_flops']:.3g} "
                          f"| {rl['useful_ratio']:.2f} |")


def compare_table(base, opt):
    """Baseline vs optimized dominant-term deltas (single-pod)."""
    print("\n| arch | shape | bottleneck | base dominant | opt dominant | delta |")
    print("|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            kb = next((d for k, d in base.items()
                       if k[:3] == (arch, shape, "16x16")), None)
            ko = next((d for k, d in opt.items()
                       if k[:3] == (arch, shape, "16x16")), None)
            if not kb or not ko:
                continue
            rb, ro = kb["roofline"], ko["roofline"]
            dom = rb["bottleneck"]
            b = rb[f"{dom}_s"]
            o = ro[f"{dom}_s"]
            delta = (o - b) / b * 100 if b else 0.0
            print(f"| {arch} | {shape} | {dom} | {_fmt_s(b)} | {_fmt_s(o)} "
                  f"| {delta:+.1f}% |")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    p.add_argument("--compare", default=None,
                   help="second artifact dir; emit baseline-vs-optimized deltas")
    p.add_argument("--section", default="all", choices=("all", "dryrun", "roofline"))
    args = p.parse_args(argv)
    recs = load(args.dir)
    print(f"{len(recs)} artifacts loaded")
    if args.compare:
        opt = load(args.compare)
        print(f"{len(opt)} optimized artifacts loaded")
        compare_table(recs, opt)
        return
    if args.section in ("all", "dryrun"):
        print("\n## §Dry-run")
        dryrun_table(recs, "16x16")
        dryrun_table(recs, "2x16x16")
    if args.section in ("all", "roofline"):
        print("\n## §Roofline (single-pod 16x16, per device)")
        roofline_table(recs)


if __name__ == "__main__":
    main()
