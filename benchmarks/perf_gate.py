"""Quantitative bench gate: fresh step times vs the committed trajectory.

The repo commits BENCH_step.json (the per-step perf trajectory, refreshed
by maintainers when perf intentionally changes); bench-smoke regenerates
it every PR.  This module turns that pair into a PASS/FAIL: for every
``step_per_bucket[impl][rung]`` present in the committed baseline, the
fresh run's ``min_us`` must stay under ``multiplier x`` the committed
``min_us``, and the (impl, rung) grid itself must not shrink — a rung
that vanishes from the fresh run is a coverage regression, not a pass.

``min_us`` is the comparison metric by design: the CI box shares cores,
so mean/median carry contention noise, but the *minimum* over a run's
samples is the noise floor — contention is strictly additive, so a real
slowdown moves the floor while a noisy neighbour cannot.  The multiplier
(``--gate-mult`` / ``$BENCH_GATE_MULT``, default 8.0) is deliberately
generous for the same reason: this gate exists to catch order-of-magnitude
regressions (an accidental recompile per step, a host sync in the hot
loop), not single-digit percent drift — the static cost-model layer
(`repro.analysis`) owns the fine-grained budget.

CLI: ``python -m benchmarks.perf_gate FRESH BASELINE [--mult M]``, or via
``python -m benchmarks.run --baseline BASELINE`` which gates the freshly
merged --json-out after the benches finish.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_MULT = 8.0


def gate_multiplier(cli_value=None) -> float:
    """Precedence: explicit CLI value > $BENCH_GATE_MULT > default 8.0."""
    if cli_value is not None:
        return float(cli_value)
    return float(os.environ.get("BENCH_GATE_MULT", DEFAULT_MULT))


def compare_step_times(fresh: dict, baseline: dict, mult: float) -> list[str]:
    """Failure messages for every (impl, rung) in the BASELINE grid whose
    fresh ``min_us`` exceeds ``mult x`` baseline, or which the fresh run
    dropped.  Extra fresh impls/rungs are fine (coverage can only grow);
    an empty list means the gate passes."""
    failures = []
    base_grid = baseline.get("step_per_bucket")
    if not isinstance(base_grid, dict) or not base_grid:
        return ["baseline has no step_per_bucket grid (regenerate it with "
                "`python -m benchmarks.run --only flat_stats`)"]
    fresh_grid = fresh.get("step_per_bucket") or {}
    for impl, rungs in sorted(base_grid.items()):
        for rung, entry in sorted(rungs.items(), key=lambda kv: int(kv[0])):
            want = entry.get("min_us")
            if want is None:
                continue
            got_entry = fresh_grid.get(impl, {}).get(rung)
            if got_entry is None:
                failures.append(
                    f"step_per_bucket[{impl}][{rung}]: missing from the "
                    f"fresh run (baseline min_us={want}) — coverage shrank")
                continue
            got = got_entry["min_us"]
            if got > mult * want:
                failures.append(
                    f"step_per_bucket[{impl}][{rung}]: fresh min_us={got} "
                    f"> {mult:g}x baseline min_us={want} "
                    f"({got / max(want, 1e-9):.1f}x)")
    return failures


def run_gate(fresh_path: str, baseline_path: str,
             mult: float | None = None) -> list[str]:
    """Load both JSONs, compare, print a verdict; returns the failures."""
    mult = gate_multiplier(mult)
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = compare_step_times(fresh, baseline, mult)
    if failures:
        print(f"perf gate FAIL ({len(failures)} regression(s), "
              f"mult={mult:g}):", flush=True)
        for msg in failures:
            print(f"  - {msg}", flush=True)
    else:
        n = sum(len(r) for r in baseline.get("step_per_bucket", {}).values())
        print(f"perf gate PASS ({n} (impl, rung) cells within "
              f"{mult:g}x of baseline)", flush=True)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.perf_gate",
        description="gate fresh BENCH_step.json step times against the "
                    "committed baseline")
    ap.add_argument("fresh", help="freshly generated BENCH_step.json")
    ap.add_argument("baseline", help="committed baseline BENCH_step.json")
    ap.add_argument("--mult", type=float, default=None,
                    help=f"regression multiplier (default $BENCH_GATE_MULT "
                         f"or {DEFAULT_MULT})")
    args = ap.parse_args(argv)
    return 1 if run_gate(args.fresh, args.baseline, args.mult) else 0


if __name__ == "__main__":
    sys.exit(main())
