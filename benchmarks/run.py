"""Benchmark harness — one benchmark per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--steps N]

Output: ``name,us_per_call,derived`` CSV rows (harness contract), where
`derived` carries the table-specific payload (loss/val-loss/avg-batch/...).

Paper tables (CPU-scale analogs of Tables 1-3 / Figure 2 — same schemes,
reduced models; the full-scale reproduction path is launch/train.py on real
hardware):
  table1_microllama   adaptive(eta sweep) vs constant vs stagewise, DDP-Norm
  table2_tinyllama    same schemes under FSDP-Norm on a 4-worker mesh
                      (subprocess with 4 host devices, like the paper's 4 GPUs)
  table3_openllama    adaptive vs constant vs stagewise, ACCUM-NORM variant
System benches:
  serve               continuous-batching serving tier under bursty
                      open-loop load (req/s, p99, warmed-rung transitions)
                      -> BENCH_serve.json
  norm_test_overhead  us/call of the eq.(5) statistic vs param count;
                      step-time overhead of testing every step
  kernel_micro        Pallas kernels (interpret) vs jnp reference oracles
  roofline_table      re-emits §Roofline terms from experiments/dryrun JSONs
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp


def _row(name, us_per_call, **derived):
    payload = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{payload}", flush=True)


# Per-step perf trajectory, written to --json-out (BENCH_step.json) so the
# numbers are tracked PR-over-PR: stats-path tail timings (tree vs flat,
# DESIGN §9) and per-step wall clock per engine bucket.
BENCH_JSON: dict = {}


# ------------------------------------------------------------ tables ----

def _train_scheme(arch, scheme, steps, *, eta=0.2, step_impl="accum_norm",
                  max_gb=64, base_gb=4, stages=None, seed=0):
    # the paper's comparison criterion: FIXED TOTAL SAMPLES for every scheme
    # (Tables 1-3 train each scheme on the same 2M sequences); steps differ.
    from repro.launch.train import TrainJob, run_training, summarize
    total_samples = steps * max_gb
    kw = dict(arch=arch, steps=10**9, total_samples=total_samples, seq_len=64,
              base_global_batch=base_gb,
              max_global_batch=max_gb, base_micro_batch=2, max_micro_batch=4,
              base_accum=2, eval_every=max(steps // 2, 1), eval_batches=2,
              data_seed=seed, step_impl=step_impl)
    if scheme == "adaptive":
        job = TrainJob(schedule="adaptive", eta=eta, **kw)
    elif scheme == "stagewise":
        job = TrainJob(schedule="stagewise",
                       stages=stages or ((0.025, base_gb), (0.025, base_gb * 4),
                                         (0.95, max_gb)), **kw)
    else:  # constant:<batch>
        b = int(scheme.split(":")[1])
        kw.update(base_global_batch=b, max_global_batch=b)
        job = TrainJob(schedule="constant", **kw)
    # repro: allow(unfenced-timing) — whole-run span; run_training/serving materializes host floats every step, so the wall clock cannot run ahead of device work
    t0 = time.time()
    hist = run_training(job)
    s = summarize(hist)
    us = (time.time() - t0) / max(s["steps"], 1) * 1e6
    return us, s


def _engine_payload(s):
    """engine_stats columns (compiles / cache hit rate / padding waste) for
    the benchmark rows — the tentpole's measurable recompile savings."""
    eng = s.get("engine")
    if not eng:
        return {}
    return {"compiles": eng["compiles"], "hit_rate": eng["hit_rate"],
            "pad_waste": eng["padding_waste"]}


def bench_table1_microllama(steps):
    """Paper Table 1: MicroLlama schemes under the norm test (CPU-scale)."""
    for scheme, eta in (("adaptive", 0.1), ("adaptive", 0.2),
                        ("constant:4", None), ("constant:64", None),
                        ("stagewise", None)):
        name = f"table1_microllama/{scheme}" + (f"_eta{eta}" if eta else "")
        us, s = _train_scheme("microllama-300m", scheme, steps, eta=eta or 0.2)
        _row(name, us, steps=s["steps"], avg_bsz=round(s["avg_batch"], 1),
             loss=round(s["best_loss"], 3), val_loss=round(s["best_val_loss"], 3),
             time_s=round(s["wall_s"], 1), **_engine_payload(s))


def bench_table2_tinyllama(steps):
    """Paper Table 2: TinyLlama under FSDP-Norm, J=4 workers (subprocess with
    4 forced host devices, mirroring the paper's 4-GPU setup)."""
    import subprocess
    code = f"""
import json, time
from repro.launch.train import TrainJob, run_training, summarize
for scheme, eta in (("adaptive", 0.08), ("constant", None), ("stagewise", None)):
    job = TrainJob(arch="tinyllama-1.1b", steps=10**9,
                   total_samples={steps} * 64, seq_len=64,
                   schedule=scheme, eta=eta or 0.2,
                   base_global_batch=8, max_global_batch=64,
                   stages=((0.025, 8), (0.025, 16), (0.95, 64)),
                   base_micro_batch=2, max_micro_batch=4, base_accum=1,
                   step_impl="fsdp_norm", mesh_data=4,
                   eval_every=10, eval_batches=2)
    t0 = time.time(); h = run_training(job); s = summarize(h)
    s["us"] = (time.time()-t0)/max(s["steps"],1)*1e6
    print("ROW", scheme, eta, json.dumps(s))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=1800)
    if res.returncode != 0:
        _row("table2_tinyllama/FAILED", 0, err=res.stderr[-200:].replace("\n", " "))
        return
    for line in res.stdout.splitlines():
        if line.startswith("ROW"):
            _, scheme, eta, payload = line.split(" ", 3)
            s = json.loads(payload)
            name = f"table2_tinyllama/{scheme}" + (
                f"_eta{eta}" if eta != "None" else "")
            _row(name, s["us"], steps=s["steps"],
                 avg_bsz=round(s["avg_batch"], 1),
                 loss=round(s["best_loss"], 3),
                 val_loss=round(s["best_val_loss"], 3))


def bench_table3_openllama(steps):
    """Paper Table 3: OpenLlama schemes (ACCUM-NORM variant, short sequences
    mirroring the paper's 512-token OpenLlama runs)."""
    for scheme, eta in (("adaptive", 0.15), ("constant:8", None),
                        ("constant:64", None), ("stagewise", None)):
        name = f"table3_openllama/{scheme}" + (f"_eta{eta}" if eta else "")
        us, s = _train_scheme("openllama-3b", scheme, steps, eta=eta or 0.15,
                              base_gb=8)
        _row(name, us, steps=s["steps"], avg_bsz=round(s["avg_batch"], 1),
             loss=round(s["best_loss"], 3), val_loss=round(s["best_val_loss"], 3),
             time_s=round(s["wall_s"], 1), **_engine_payload(s))


def bench_engine_cache(steps):
    """Recompile savings of the bucketed engine (DESIGN §8): the same
    adaptive 4→64 schedule with the bucket ladder on vs off, plus the
    AOT-warmup variant.  Derived columns: traces compiled, cache hit rate,
    padding waste, wall seconds.  The ladder-on walls also land in
    BENCH_step.json['warmup_overlap'] — what overlapping the next rung's
    compile with training saves end-to-end — and a 2-process
    file-coordinated run emits the per-rank barrier-wait timings
    (BENCH_step.json['coordination'], DESIGN §8.1)."""
    from repro.launch.train import TrainJob, run_training, summarize
    walls = {}
    for tag, ladder, warm in (("ladder_auto", "auto", False),
                              ("ladder_auto_aot", "auto", True),
                              ("ladder_off", "off", False)):
        job = TrainJob(arch="llama3.2-1b", steps=min(steps, 25), seq_len=64,
                       base_global_batch=4, max_global_batch=64,
                       base_micro_batch=2, max_micro_batch=4, base_accum=2,
                       eta=0.12, step_impl="accum_norm", eval_every=0,
                       bucket_ladder=ladder, aot_warmup=warm)
        # repro: allow(unfenced-timing) — whole-run span; run_training/serving materializes host floats every step, so the wall clock cannot run ahead of device work
        t0 = time.time()
        h = run_training(job)
        s = summarize(h)
        walls[tag] = round(time.time() - t0, 3)
        payload = _engine_payload(s) or {"compiles": "n/a"}
        _row(f"engine_cache/{tag}",
             (time.time() - t0) / max(s["steps"], 1) * 1e6,
             steps=s["steps"], avg_bsz=round(s["avg_batch"], 1),
             wall_s=round(s["wall_s"], 1), **payload)
    BENCH_JSON["warmup_overlap"] = {
        "sync_wall_s": walls["ladder_auto"],
        "aot_wall_s": walls["ladder_auto_aot"],
        "no_ladder_wall_s": walls["ladder_off"],
        "saved_s": round(walls["ladder_auto"] - walls["ladder_auto_aot"], 3)}
    _bench_coordination()


_COORD_RANK_CODE = """
import json, sys
from repro.launch.train import TrainJob, run_training
rank, coord_dir, cache_dir = int(sys.argv[1]), sys.argv[2], sys.argv[3]
job = TrainJob(arch="llama3.2-1b", schedule="stagewise",
               stages=((0.5, 4), (0.5, 8)), steps=12, total_samples=48,
               seq_len=16, base_global_batch=4, max_global_batch=8,
               base_micro_batch=2, max_micro_batch=2, base_accum=2,
               step_impl="accum_norm", eval_every=0, aot_warmup=True,
               coord="file", coord_dir=coord_dir, coord_rank=rank,
               coord_world=2, coord_timeout=120.0, compile_cache=cache_dir)
h = run_training(job)
print("ENG", json.dumps(h["engine"]))
"""


def _bench_coordination():
    """Two file-coordinated processes over a stagewise 4→8 increase: the
    multi-host half of the engine story.  Reports per-rank barrier crossings
    and wait time (the coordination overhead a fleet pays per rung
    transition) plus warmups/hit-rate proving the post-increase step was a
    cache hit on both hosts."""
    import subprocess
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        coord, cache = os.path.join(tmp, "coord"), os.path.join(tmp, "cc")
        procs = [subprocess.Popen(
            [sys.executable, "-c", _COORD_RANK_CODE, str(r), coord, cache],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env) for r in range(2)]
        out = {}
        try:
            for r, p in enumerate(procs):
                stdout, stderr = p.communicate(timeout=600)
                if p.returncode != 0:
                    _row("engine_coord/FAILED", 0,
                         err=stderr[-200:].replace("\n", " "))
                    return
                eng = json.loads(next(l for l in stdout.splitlines()
                                      if l.startswith("ENG")).split(" ", 1)[1])
                out[f"rank{r}"] = {k: eng[k] for k in
                                   ("barriers", "barrier_wait_s", "desyncs",
                                    "warmups", "compiles", "hits", "hit_rate",
                                    "disk_cache_hits")}
                _row(f"engine_coord/rank{r}", eng["barrier_wait_s"] * 1e6,
                     barriers=eng["barriers"], warmups=eng["warmups"],
                     hit_rate=eng["hit_rate"], desyncs=eng["desyncs"])
        finally:
            # a failed (or timed-out) rank must not leave its peer orphaned
            # inside the tmp dir the with-block is about to delete
            for p in procs:
                if p.poll() is None:
                    p.kill()
                    p.communicate()
        BENCH_JSON["coordination"] = out


# ----------------------------------------------------- system benches ----

def _flat_bench_tree(d: int, layers: int):
    """Transformer-like gradient pytree (deep-narrow shapes hit the
    leaf-count regime the flat path targets)."""
    t = {"embed": jnp.zeros((1024, d))}
    for i in range(layers):
        t[f"layer{i}"] = {
            "qkv": jnp.zeros((d, 3 * d)), "o": jnp.zeros((d, d)),
            "mlp_in": jnp.zeros((d, 4 * d)), "mlp_out": jnp.zeros((4 * d, d)),
            "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
        }
    return t


def _bench_pair(fa, aa, fb, ab, reps=6):
    """Interleaved timing (this box is noisy): returns (us_a, us_b)."""
    jax.block_until_ready(fa(*aa))
    jax.block_until_ready(fb(*ab))
    ta = tb = 0.0
    for _ in range(reps):
        t0 = time.time(); jax.block_until_ready(fa(*aa)); ta += time.time() - t0
        t0 = time.time(); jax.block_until_ready(fb(*ab)); tb += time.time() - t0
    return ta / reps * 1e6, tb / reps * 1e6


def bench_flat_stats(steps):
    """DESIGN §9 microbenchmark: the per-step statistics+update tail on its
    native layout — leaf-by-leaf pytree walk (tree) vs bucketed flat buffers
    + fused single-pass kernels (flat).  Rows land in the CSV and in
    BENCH_step.json['stats_path']; the grad-packing overhead (what a step
    pays to enter the flat layout when gradients arrive as a pytree) is
    measured separately and never hidden inside the tail numbers."""
    from repro.core.norm_test import tree_sqdiff, tree_sqnorm
    from repro.distributed.flatbuf import FlatLayout
    from repro.kernels import ops
    from repro.optim.adamw import (
        AdamWConfig, init_adamw, adamw_update, adamw_update_buffers,
        flat_opt_state)

    tiny = bool(os.environ.get("BENCH_TINY"))
    shapes = ((("tiny_0.2M", 64, 4),) if tiny else
              (("deep_19M", 128, 96), ("wide_13M", 512, 4)))
    cfg = AdamWConfig()
    reps = 3 if tiny else 6

    def randlike(seed, tree):
        leaves, td = jax.tree.flatten(tree)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
        return td.unflatten([jax.random.normal(k, l.shape)
                             for k, l in zip(keys, leaves)])

    for tag, d, layers in shapes:
        like = _flat_bench_tree(d, layers)
        n = sum(x.size for x in jax.tree.leaves(like))
        params = randlike(0, like)
        gj, g = randlike(1, like), randlike(2, like)
        state = init_adamw(params)
        state["m"] = randlike(3, like)
        state["v"] = jax.tree.map(jnp.abs, randlike(4, like))
        layout = FlatLayout.from_tree(params)
        pb, gjb, gb = (layout.flatten(t) for t in (params, gj, g))
        fstate = flat_opt_state(params, state)
        mb, vb = list(fstate["m"]), list(fstate["v"])
        lr, count = jnp.float32(1e-3), state["count"]

        def tree_tail(params, gj, g, m, v, lr):
            var = tree_sqdiff(gj, g)
            gsq = tree_sqnorm(g)
            st = {"m": m, "v": v, "count": count}
            p2, st2, gn = adamw_update(params, g, st, cfg, lr)
            return var, gsq, gn, p2, st2

        def flat_tail(pb, gjb, gb, mb, vb, lr):
            var = gsq = jnp.zeros((), jnp.float32)
            for a, b in zip(gjb, gb):
                dd, qq = ops.stats_flat(a, b)
                var += dd
                gsq += qq
            out = adamw_update_buffers(pb, gb, mb, vb, cfg, lr, count,
                                       grad_sqnorm=gsq)
            return (var, gsq) + tuple(out)

        tree_us, flat_us = _bench_pair(
            jax.jit(tree_tail), (params, gj, g, state["m"], state["v"], lr),
            jax.jit(flat_tail), (pb, gjb, gb, mb, vb, lr), reps=reps)
        pack = jax.jit(layout.flatten)
        jax.block_until_ready(pack(g))
        t0 = time.time()
        for _ in range(reps):
            out = pack(g)
        jax.block_until_ready(out)
        pack_us = (time.time() - t0) / reps * 1e6

        entry = {"params": n, "leaves": layout.num_leaves,
                 "buckets": layout.num_buffers,
                 "tree_us": round(tree_us, 1), "flat_us": round(flat_us, 1),
                 "speedup": round(tree_us / max(flat_us, 1e-9), 3),
                 "pack_grads_us": round(pack_us, 1)}
        BENCH_JSON.setdefault("stats_path", {})[tag] = entry
        _row(f"flat_stats/{tag}/tree", tree_us, params=n,
             leaves=layout.num_leaves)
        _row(f"flat_stats/{tag}/flat", flat_us, params=n,
             buckets=layout.num_buffers, speedup=entry["speedup"],
             pack_us=round(pack_us, 1))

        # unflatten-under-grad adjoint characterization (ROADMAP: the
        # slice-transpose cost that gates flat-resident params, DESIGN §10).
        # Three ways to obtain the flat gradient of the same loss:
        #   pad_add    — autodiff straight through `unflatten` (XLA's native
        #                slice adjoint: per-slot zero-pad + N-way add)
        #   pack_vjp   — `unflatten_for_grad`'s explicit adjoint (one
        #                ravel+concat per bucket)
        #   grad_pack  — the OLD dataflow: materialize the gradient pytree,
        #                then flatten it (what flat residency deletes)
        def adjoint_loss(t):
            return tree_sqdiff(t, params)        # nonlinear enough, 1 read

        pad_add = jax.jit(jax.grad(
            lambda bufs: adjoint_loss(layout.unflatten(list(bufs)))))
        pack_vjp = jax.jit(jax.grad(
            lambda bufs: adjoint_loss(layout.unflatten_for_grad(bufs))))
        grad_pack = jax.jit(
            lambda t: layout.flatten(jax.grad(adjoint_loss)(t)))
        bufs = tuple(pb)
        pad_us, vjp_us = _bench_pair(pad_add, (bufs,), pack_vjp, (bufs,),
                                     reps=reps)
        _, gp_us = _bench_pair(pack_vjp, (bufs,), grad_pack, (g,), reps=reps)
        adj = {"pad_add_us": round(pad_us, 1),
               "pack_vjp_us": round(vjp_us, 1),
               "tree_grad_pack_us": round(gp_us, 1)}
        BENCH_JSON.setdefault("unflatten_adjoint", {})[tag] = adj
        _row(f"flat_stats/{tag}/unflatten_adjoint", vjp_us, **adj)

    _bench_step_per_bucket(4 if tiny else min(steps, 12))


def _bench_step_per_bucket(nsteps):
    """Per-step wall clock at EVERY ladder rung, across the three residency
    paths — the engine/bucket half of BENCH_step.json:

      tree          — stats_impl=tree,  params_impl=tree (the oracle)
      flat          — stats_impl=flat,  params_impl=tree (DESIGN §9: fused
                      tail, mean gradient packed once per step)
      flat_resident — stats_impl=flat,  params_impl=flat (DESIGN §10:
                      gradients born flat, ZERO packs per step — its
                      pack_us is structurally 0, guarded by the tier-1
                      `count_layout_ops` marker-eqn test)

    Each rung gets its own constant-batch FSDP-Norm step (the paper's
    primary distributed step, and the one where flat residency deletes the
    most per-step layout movement: both gradient packs, the params pack,
    and the new-params unflatten) pinned to that rung's capacity (the old
    adaptive run only ever produced steady-state timings for the top rung
    it settled into), the compile step is excluded (warmup call), and the
    flat path's per-step gradient PACK time is measured separately against
    the model's own parameter tree — never hidden inside the step means."""
    from repro.core.schedule import bucket_ladder
    from repro.distributed.flatbuf import FlatLayout

    from repro.compat import set_mesh
    from repro.configs import get_smoke_config
    from repro.data.pipeline import MarkovTokens, make_batch
    from repro.distributed.train_step import make_fsdp_norm_step
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig, init_adamw, init_adamw_flat

    IMPLS = (("tree", "tree", "tree"), ("flat", "flat", "tree"),
             ("flat_resident", "flat", "flat"))
    base_gb, max_gb = 4, 16
    ladder = bucket_ladder(workers=1, micro_batch=2, max_micro_batch=2,
                           base_accum=2, base_global=base_gb,
                           max_global=max_gb)
    out = {tag: {} for tag, _, _ in IMPLS}

    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    opt_cfg = AdamWConfig()
    lr = jnp.float32(1e-3)
    params_like = model.init(jax.random.PRNGKey(0))
    # the deltas at stake (one gradient pack, ~hundreds of µs) sit far below
    # this shared 2-core box's run-to-run drift (the agent harness shares
    # the cores), so the three impls are timed STEP-BY-STEP round-robin —
    # rotating the cycle order every iteration — instead of run-by-run:
    # drift at the seconds scale hits all three equally
    reps = 2 if os.environ.get("BENCH_TINY") else 5
    with set_mesh(mesh):
        for rung in ladder:
            batch = jax.tree.map(jnp.asarray,
                                 make_batch(src, 0, rung, 32))
            sds_b = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
            runners = {}
            for tag, stats_impl, params_impl in IMPLS:
                params = model.init(jax.random.PRNGKey(0))
                wrap, _, _ = make_fsdp_norm_step(
                    model, opt_cfg, mesh, stats_impl=stats_impl,
                    params_impl=params_impl, params_like=params)
                layout = wrap.flat_layout
                opt = (init_adamw_flat(params, layout=layout)
                       if stats_impl == "flat" else init_adamw(params))
                if params_impl == "flat":
                    params = tuple(layout.flatten(params))
                fn = wrap(sds_b)
                # warmup = compile (the steps donate: thread the state)
                params, opt, _ = fn(params, opt, batch, lr)
                jax.block_until_ready(params)
                runners[tag] = [fn, params, opt]
            dts = {tag: [] for tag in runners}
            for i in range(nsteps * reps):
                rot = i % len(IMPLS)
                for tag, _, _ in IMPLS[rot:] + IMPLS[:rot]:
                    r = runners[tag]
                    t0 = time.time()
                    p, o, _ = r[0](r[1], r[2], batch, lr)
                    jax.block_until_ready(p)
                    dts[tag].append(time.time() - t0)
                    r[1], r[2] = p, o
            for tag, samples in dts.items():
                # the box shares its 2 cores with other processes, so
                # samples are bimodal (quiet vs contended) with a heavy
                # straggler tail; the headline `mean_us` is trimmed at 2x
                # the median, with `median_us` and `min_us` (noise floor —
                # contention is strictly additive) alongside so the
                # flat-resident-vs-flat delta can be read against the
                # noise: at 3-bucket smoke scale the two are within a few
                # percent either way (the structural difference — zero
                # packs — is pinned by the tier-1 op-count test, and the
                # deep-tree stats_path/unflatten_adjoint shapes above are
                # where it is measurable)
                med = sorted(samples)[len(samples) // 2]
                kept = [d for d in samples if d <= 2 * med] or samples
                out[tag][str(rung.global_batch)] = {
                    "steps": len(kept),
                    "outliers_dropped": len(samples) - len(kept),
                    "mean_us": round(sum(kept) / len(kept) * 1e6, 1),
                    "median_us": round(med * 1e6, 1),
                    "min_us": round(min(samples) * 1e6, 1)}
    for impl, rungs in out.items():
        out[impl] = dict(sorted(rungs.items(), key=lambda kv: int(kv[0])))

    # pack overhead, reported separately (param-SHAPED tree, same layout
    # the flat steps use — pack time is shape-only, the values don't
    # matter): what one flatten of the gradient-shaped tree costs.  The
    # flat-resident path never performs it — its steady-state pack count is
    # 0 (tier-1 op-count guarded), so its pack_us is identically 0.
    layout = FlatLayout.from_tree(params_like)
    pack = jax.jit(layout.flatten)
    jax.block_until_ready(pack(params_like))
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        packed = pack(params_like)
    jax.block_until_ready(packed)
    pack_us = round((time.time() - t0) / reps * 1e6, 1)
    for e in out["flat"].values():
        e["pack_us"] = pack_us
    for e in out["flat_resident"].values():
        e["pack_us"] = 0.0
        e["packs_per_step"] = 0

    for tag, rungs in out.items():
        for k, e in rungs.items():
            _row(f"flat_stats/step_bucket{k}/{tag}", e["mean_us"],
                 steps=e["steps"], **({"pack_us": e["pack_us"]}
                                      if "pack_us" in e else {}))
    BENCH_JSON["step_per_bucket"] = out


def bench_serve(steps):
    """Continuous-batching serving tier (DESIGN §11) under bursty open-loop
    load: sustained req/s, p50/p99 request latency, decode tok/s, engine
    cache counters, and the steady-state probe (a request-batch-size change
    served from a warmed rung: transition cache hits, ZERO new compiles).
    Lands in BENCH_serve.json — its own trajectory file, separate from the
    training-side BENCH_step.json."""
    from repro.launch.serve import run_continuous_serving
    tiny = bool(os.environ.get("BENCH_TINY"))
    load = dict(max_slots=8, prompt_len=4, gen_len=8,
                load_steps=30 if tiny else max(steps, 60),
                arrival_rate=0.5, burst_every=10 if tiny else 20,
                burst_size=5, aot_warmup=True)
    # repro: allow(unfenced-timing) — whole-run span; run_training/serving materializes host floats every step, so the wall clock cannot run ahead of device work
    t0 = time.time()
    res = run_continuous_serving("llama3.2-1b", smoke=True, **load)
    us = (time.time() - t0) / max(res["engine"]["steps"], 1) * 1e6
    _row("serve/bursty", us,
         req_per_s=round(res["sustained_req_per_s"], 2),
         p99_s=round(res["p99_latency_s"], 3),
         tok_per_s=round(res["decode_tok_per_s"], 1),
         hit_rate=res["engine"]["hit_rate"],
         steady_hit=res["probe"]["steady_state_transition_hit"])
    out = {"load": load, **{k: v for k, v in res.items() if k != "rung_trace"},
           "rung_trace": res["rung_trace"][:64]}
    path = os.path.join(os.getcwd(), "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")


def bench_norm_test_overhead(steps):
    """us/call of the eq.(5) reduction at increasing gradient sizes, plus
    step-time overhead of test_interval=1 vs no testing."""
    from repro.core.norm_test import tree_sqdiff, tree_sqnorm
    key = jax.random.PRNGKey(0)
    for n in (1 << 16, 1 << 20, 1 << 23):
        g1 = {"w": jax.random.normal(key, (n,))}
        g2 = {"w": jax.random.normal(jax.random.PRNGKey(1), (n,))}
        f = jax.jit(lambda a, b: (tree_sqdiff(a, b), tree_sqnorm(b)))
        jax.block_until_ready(f(g1, g2)[0])
        t0 = time.time()
        reps = 20
        for _ in range(reps):
            r, _ = f(g1, g2)
        r.block_until_ready()
        us = (time.time() - t0) / reps * 1e6
        _row(f"norm_test_stat/{n}", us, params=n,
             gb_per_s=round(2 * 4 * n / (us / 1e6) / 1e9, 2))

    from repro.launch.train import TrainJob, run_training
    for tag, interval in (("test_every_step", 1), ("test_off", 10**9)):
        job = TrainJob(arch="llama3.2-1b", steps=min(steps, 12), seq_len=64,
                       base_global_batch=8, max_global_batch=8,
                       base_micro_batch=2, max_micro_batch=2, base_accum=2,
                       step_impl="accum_norm", test_interval=interval,
                       eval_every=0)
        t0 = time.time()
        hist = run_training(job)
        us = (time.time() - t0) / len(hist["step"]) * 1e6
        _row(f"norm_test_overhead/{tag}", us, steps=len(hist["step"]))


def bench_kernel_micro(steps):
    """Pallas kernels (interpret mode on CPU — correctness path) vs oracles."""
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)

    def timeit(f, *args, reps=5):
        jax.block_until_ready(f(*args))
        t0 = time.time()
        for _ in range(reps):
            out = f(*args)
        jax.block_until_ready(out)
        return (time.time() - t0) / reps * 1e6

    n = 1 << 20
    x = jax.random.normal(key, (n,))
    y = x + 0.01
    _row("kernel/sqdiff_norm_pallas", timeit(lambda a, b: ops.sqdiff_norm(a, b), x, y))
    _row("kernel/sqdiff_norm_ref", timeit(jax.jit(ref.sqdiff_norm_ref), x, y))

    b, t, h, d = 1, 512, 4, 64
    q = jax.random.normal(key, (b, t, h, d))
    k = jax.random.normal(key, (b, t, h, d))
    v = jax.random.normal(key, (b, t, h, d))
    _row("kernel/flash_attention_pallas",
         timeit(lambda a, c, e: ops.flash_attention(a, c, e, block_q=256,
                                                    block_kv=256), q, k, v))
    _row("kernel/attention_ref",
         timeit(jax.jit(lambda a, c, e: ref.attention_ref(a, c, e)), q, k, v))

    xw = jax.random.normal(key, (4096, 1024))
    sc = jnp.ones((1024,))
    _row("kernel/rmsnorm_pallas", timeit(lambda a, s: ops.rmsnorm(a, s), xw, sc))
    _row("kernel/rmsnorm_ref", timeit(jax.jit(ref.rmsnorm_ref), xw, sc))


def bench_roofline_table(steps):
    """Emit §Roofline rows from the dry-run artifacts (single-pod)."""
    base = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "experiments", "dryrun")
    for path in sorted(glob.glob(os.path.join(base, "*__16x16.json"))):
        d = json.load(open(path))
        rl = d["roofline"]
        _row(f"roofline/{d['arch']}/{d['shape']}", d["compile_s"] * 1e6,
             compute_s=f"{rl['compute_s']:.3g}",
             memory_s=f"{rl['memory_s']:.3g}",
             collective_s=f"{rl['collective_s']:.3g}",
             bottleneck=rl["bottleneck"],
             useful=f"{rl['useful_ratio']:.2f}")


def bench_norm_test_knobs(steps):
    """Beyond-paper knobs (DESIGN §7.4): test interval and EMA smoothing of
    T_k — overhead amortization vs schedule fidelity."""
    from repro.launch.train import TrainJob, run_training, summarize
    for tag, interval, ema in (("interval1", 1, 0.0), ("interval5", 5, 0.0),
                               ("interval1_ema0.7", 1, 0.7)):
        job = TrainJob(arch="llama3.2-1b", steps=10**9,
                       total_samples=steps * 32, seq_len=64,
                       base_global_batch=4, max_global_batch=64,
                       base_micro_batch=2, max_micro_batch=4, base_accum=2,
                       eta=0.15, step_impl="accum_norm",
                       test_interval=interval, ema=ema, eval_every=0)
        import time as _t
        t0 = _t.time()
        h = run_training(job)
        ss = summarize(h)
        _row(f"norm_test_knobs/{tag}", (_t.time() - t0) / max(ss["steps"], 1) * 1e6,
             steps=ss["steps"], avg_bsz=round(ss["avg_batch"], 1),
             loss=round(ss["best_loss"], 3),
             final_bsz=h["global_batch"][-1])


def bench_gns_predict(steps):
    """Predictive GNS controller (DESIGN §14): the same adaptive schedule
    with the predictor on vs off, AOT warmup enabled in both.  Emits the
    prediction trajectory into BENCH_step.json['gns_prediction'] — the
    predicted vs actual rung-crossing step and whether warmup turned each
    measured rung transition into a cache hit (the acceptance claim: under
    prediction, transition_hits == transitions with zero foreground compiles
    at a transition)."""
    from repro.launch.train import TrainJob, run_training, summarize
    out = {}
    for tag, predict in (("predict", True), ("baseline", False)):
        job = TrainJob(arch="llama3.2-1b", steps=min(steps, 25), seq_len=64,
                       base_global_batch=32, max_global_batch=64,
                       base_micro_batch=2, max_micro_batch=2, base_accum=2,
                       eta=0.12, step_impl="accum_norm", eval_every=0,
                       aot_warmup=True, predict=predict)
        # repro: allow(unfenced-timing) — whole-run span; run_training/serving materializes host floats every step, so the wall clock cannot run ahead of device work
        t0 = time.time()
        h = run_training(job)
        s = summarize(h)
        wall = round(time.time() - t0, 3)
        eng = h["engine"]
        # actual crossing: first step whose executed batch left the base rung
        base_gb = h["global_batch"][0]
        actual = next((st for st, gb in zip(h["step"], h["global_batch"])
                       if gb > base_gb), -1)
        # predicted crossing: first step that forecast a rung above base
        predicted = next((st for st, r in zip(h["step"], h["pred_rung"])
                          if r > base_gb), -1)
        out[tag] = {
            "wall_s": wall,
            "transitions": eng["transitions"],
            "transition_hits": eng["transition_hits"],
            "compiles": eng["compiles"],
            "warmups": eng["warmups"],
            "actual_crossing_step": actual,
            "predicted_crossing_step": predicted,
            "pred_rung_trace": h["pred_rung"],
            "pred_eta_trace": [round(e, 3) for e in h["pred_eta"]],
            "batch_trace": h["global_batch"],
        }
        _row(f"gns_predict/{tag}", wall / max(s["steps"], 1) * 1e6,
             steps=s["steps"], transitions=eng["transitions"],
             transition_hits=eng["transition_hits"],
             compiles=eng["compiles"], actual_cross=actual,
             predicted_cross=predicted)
    BENCH_JSON["gns_prediction"] = out


BENCHES = {
    "table1_microllama": bench_table1_microllama,
    "table2_tinyllama": bench_table2_tinyllama,
    "table3_openllama": bench_table3_openllama,
    "engine_cache": bench_engine_cache,
    "gns_predict": bench_gns_predict,
    "serve": bench_serve,
    "flat_stats": bench_flat_stats,
    "norm_test_overhead": bench_norm_test_overhead,
    "norm_test_knobs": bench_norm_test_knobs,
    "kernel_micro": bench_kernel_micro,
    "roofline_table": bench_roofline_table,
}


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated bench names (default: all)")
    p.add_argument("--steps", type=int, default=40)
    p.add_argument("--json-out", default="BENCH_step.json",
                   help="where the per-step perf trajectory JSON lands; "
                        "existing top-level keys from other benches are "
                        "preserved (merge-update, so --only runs don't "
                        "clobber the rest of the trajectory)")
    p.add_argument("--baseline", default=None,
                   help="committed BENCH_step.json to gate the fresh "
                        "step_per_bucket times against (perf_gate; exits 1 "
                        "on a measured regression)")
    p.add_argument("--gate-mult", type=float, default=None,
                   help="gate multiplier (default $BENCH_GATE_MULT or 8.0)")
    args = p.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if only and (unknown := only - set(BENCHES)):
        p.error(f"unknown bench(es): {sorted(unknown)}")
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if only and name not in only:
            continue
        fn(args.steps)
    if BENCH_JSON and args.json_out:
        merged = {}
        if os.path.exists(args.json_out):
            try:
                with open(args.json_out) as f:
                    merged = json.load(f)
            except (OSError, json.JSONDecodeError):
                merged = {}
        merged.update(BENCH_JSON)
        with open(args.json_out, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.baseline:
        # gate AFTER the merge so the comparison sees the full trajectory
        from benchmarks.perf_gate import run_gate
        if run_gate(args.json_out, args.baseline, args.gate_mult):
            raise SystemExit(1)


if __name__ == "__main__":
    main()
