"""Benchmark harness — one benchmark per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--steps N]

Output: ``name,us_per_call,derived`` CSV rows (harness contract), where
`derived` carries the table-specific payload (loss/val-loss/avg-batch/...).

Paper tables (CPU-scale analogs of Tables 1-3 / Figure 2 — same schemes,
reduced models; the full-scale reproduction path is launch/train.py on real
hardware):
  table1_microllama   adaptive(eta sweep) vs constant vs stagewise, DDP-Norm
  table2_tinyllama    same schemes under FSDP-Norm on a 4-worker mesh
                      (subprocess with 4 host devices, like the paper's 4 GPUs)
  table3_openllama    adaptive vs constant vs stagewise, ACCUM-NORM variant
System benches:
  norm_test_overhead  us/call of the eq.(5) statistic vs param count;
                      step-time overhead of testing every step
  kernel_micro        Pallas kernels (interpret) vs jnp reference oracles
  roofline_table      re-emits §Roofline terms from experiments/dryrun JSONs
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp


def _row(name, us_per_call, **derived):
    payload = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{payload}", flush=True)


# ------------------------------------------------------------ tables ----

def _train_scheme(arch, scheme, steps, *, eta=0.2, step_impl="accum_norm",
                  max_gb=64, base_gb=4, stages=None, seed=0):
    # the paper's comparison criterion: FIXED TOTAL SAMPLES for every scheme
    # (Tables 1-3 train each scheme on the same 2M sequences); steps differ.
    from repro.launch.train import TrainJob, run_training, summarize
    total_samples = steps * max_gb
    kw = dict(arch=arch, steps=10**9, total_samples=total_samples, seq_len=64,
              base_global_batch=base_gb,
              max_global_batch=max_gb, base_micro_batch=2, max_micro_batch=4,
              base_accum=2, eval_every=max(steps // 2, 1), eval_batches=2,
              data_seed=seed, step_impl=step_impl)
    if scheme == "adaptive":
        job = TrainJob(schedule="adaptive", eta=eta, **kw)
    elif scheme == "stagewise":
        job = TrainJob(schedule="stagewise",
                       stages=stages or ((0.025, base_gb), (0.025, base_gb * 4),
                                         (0.95, max_gb)), **kw)
    else:  # constant:<batch>
        b = int(scheme.split(":")[1])
        kw.update(base_global_batch=b, max_global_batch=b)
        job = TrainJob(schedule="constant", **kw)
    t0 = time.time()
    hist = run_training(job)
    s = summarize(hist)
    us = (time.time() - t0) / max(s["steps"], 1) * 1e6
    return us, s


def _engine_payload(s):
    """engine_stats columns (compiles / cache hit rate / padding waste) for
    the benchmark rows — the tentpole's measurable recompile savings."""
    eng = s.get("engine")
    if not eng:
        return {}
    return {"compiles": eng["compiles"], "hit_rate": eng["hit_rate"],
            "pad_waste": eng["padding_waste"]}


def bench_table1_microllama(steps):
    """Paper Table 1: MicroLlama schemes under the norm test (CPU-scale)."""
    for scheme, eta in (("adaptive", 0.1), ("adaptive", 0.2),
                        ("constant:4", None), ("constant:64", None),
                        ("stagewise", None)):
        name = f"table1_microllama/{scheme}" + (f"_eta{eta}" if eta else "")
        us, s = _train_scheme("microllama-300m", scheme, steps, eta=eta or 0.2)
        _row(name, us, steps=s["steps"], avg_bsz=round(s["avg_batch"], 1),
             loss=round(s["best_loss"], 3), val_loss=round(s["best_val_loss"], 3),
             time_s=round(s["wall_s"], 1), **_engine_payload(s))


def bench_table2_tinyllama(steps):
    """Paper Table 2: TinyLlama under FSDP-Norm, J=4 workers (subprocess with
    4 forced host devices, mirroring the paper's 4-GPU setup)."""
    import subprocess
    code = f"""
import json, time
from repro.launch.train import TrainJob, run_training, summarize
for scheme, eta in (("adaptive", 0.08), ("constant", None), ("stagewise", None)):
    job = TrainJob(arch="tinyllama-1.1b", steps=10**9,
                   total_samples={steps} * 64, seq_len=64,
                   schedule=scheme, eta=eta or 0.2,
                   base_global_batch=8, max_global_batch=64,
                   stages=((0.025, 8), (0.025, 16), (0.95, 64)),
                   base_micro_batch=2, max_micro_batch=4, base_accum=1,
                   step_impl="fsdp_norm", mesh_data=4,
                   eval_every=10, eval_batches=2)
    t0 = time.time(); h = run_training(job); s = summarize(h)
    s["us"] = (time.time()-t0)/max(s["steps"],1)*1e6
    print("ROW", scheme, eta, json.dumps(s))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=1800)
    if res.returncode != 0:
        _row("table2_tinyllama/FAILED", 0, err=res.stderr[-200:].replace("\n", " "))
        return
    for line in res.stdout.splitlines():
        if line.startswith("ROW"):
            _, scheme, eta, payload = line.split(" ", 3)
            s = json.loads(payload)
            name = f"table2_tinyllama/{scheme}" + (
                f"_eta{eta}" if eta != "None" else "")
            _row(name, s["us"], steps=s["steps"],
                 avg_bsz=round(s["avg_batch"], 1),
                 loss=round(s["best_loss"], 3),
                 val_loss=round(s["best_val_loss"], 3))


def bench_table3_openllama(steps):
    """Paper Table 3: OpenLlama schemes (ACCUM-NORM variant, short sequences
    mirroring the paper's 512-token OpenLlama runs)."""
    for scheme, eta in (("adaptive", 0.15), ("constant:8", None),
                        ("constant:64", None), ("stagewise", None)):
        name = f"table3_openllama/{scheme}" + (f"_eta{eta}" if eta else "")
        us, s = _train_scheme("openllama-3b", scheme, steps, eta=eta or 0.15,
                              base_gb=8)
        _row(name, us, steps=s["steps"], avg_bsz=round(s["avg_batch"], 1),
             loss=round(s["best_loss"], 3), val_loss=round(s["best_val_loss"], 3),
             time_s=round(s["wall_s"], 1), **_engine_payload(s))


def bench_engine_cache(steps):
    """Recompile savings of the bucketed engine (DESIGN §8): the same
    adaptive 4→64 schedule with the bucket ladder on vs off, plus the
    AOT-warmup variant.  Derived columns: traces compiled, cache hit rate,
    padding waste, wall seconds."""
    from repro.launch.train import TrainJob, run_training, summarize
    for tag, ladder, warm in (("ladder_auto", "auto", False),
                              ("ladder_auto_aot", "auto", True),
                              ("ladder_off", "off", False)):
        job = TrainJob(arch="llama3.2-1b", steps=min(steps, 25), seq_len=64,
                       base_global_batch=4, max_global_batch=64,
                       base_micro_batch=2, max_micro_batch=4, base_accum=2,
                       eta=0.12, step_impl="accum_norm", eval_every=0,
                       bucket_ladder=ladder, aot_warmup=warm)
        t0 = time.time()
        h = run_training(job)
        s = summarize(h)
        payload = _engine_payload(s) or {"compiles": "n/a"}
        _row(f"engine_cache/{tag}",
             (time.time() - t0) / max(s["steps"], 1) * 1e6,
             steps=s["steps"], avg_bsz=round(s["avg_batch"], 1),
             wall_s=round(s["wall_s"], 1), **payload)


# ----------------------------------------------------- system benches ----

def bench_norm_test_overhead(steps):
    """us/call of the eq.(5) reduction at increasing gradient sizes, plus
    step-time overhead of test_interval=1 vs no testing."""
    from repro.core.norm_test import tree_sqdiff, tree_sqnorm
    key = jax.random.PRNGKey(0)
    for n in (1 << 16, 1 << 20, 1 << 23):
        g1 = {"w": jax.random.normal(key, (n,))}
        g2 = {"w": jax.random.normal(jax.random.PRNGKey(1), (n,))}
        f = jax.jit(lambda a, b: (tree_sqdiff(a, b), tree_sqnorm(b)))
        jax.block_until_ready(f(g1, g2)[0])
        t0 = time.time()
        reps = 20
        for _ in range(reps):
            r, _ = f(g1, g2)
        r.block_until_ready()
        us = (time.time() - t0) / reps * 1e6
        _row(f"norm_test_stat/{n}", us, params=n,
             gb_per_s=round(2 * 4 * n / (us / 1e6) / 1e9, 2))

    from repro.launch.train import TrainJob, run_training
    for tag, interval in (("test_every_step", 1), ("test_off", 10**9)):
        job = TrainJob(arch="llama3.2-1b", steps=min(steps, 12), seq_len=64,
                       base_global_batch=8, max_global_batch=8,
                       base_micro_batch=2, max_micro_batch=2, base_accum=2,
                       step_impl="accum_norm", test_interval=interval,
                       eval_every=0)
        t0 = time.time()
        hist = run_training(job)
        us = (time.time() - t0) / len(hist["step"]) * 1e6
        _row(f"norm_test_overhead/{tag}", us, steps=len(hist["step"]))


def bench_kernel_micro(steps):
    """Pallas kernels (interpret mode on CPU — correctness path) vs oracles."""
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)

    def timeit(f, *args, reps=5):
        jax.block_until_ready(f(*args))
        t0 = time.time()
        for _ in range(reps):
            out = f(*args)
        jax.block_until_ready(out)
        return (time.time() - t0) / reps * 1e6

    n = 1 << 20
    x = jax.random.normal(key, (n,))
    y = x + 0.01
    _row("kernel/sqdiff_norm_pallas", timeit(lambda a, b: ops.sqdiff_norm(a, b), x, y))
    _row("kernel/sqdiff_norm_ref", timeit(jax.jit(ref.sqdiff_norm_ref), x, y))

    b, t, h, d = 1, 512, 4, 64
    q = jax.random.normal(key, (b, t, h, d))
    k = jax.random.normal(key, (b, t, h, d))
    v = jax.random.normal(key, (b, t, h, d))
    _row("kernel/flash_attention_pallas",
         timeit(lambda a, c, e: ops.flash_attention(a, c, e, block_q=256,
                                                    block_kv=256), q, k, v))
    _row("kernel/attention_ref",
         timeit(jax.jit(lambda a, c, e: ref.attention_ref(a, c, e)), q, k, v))

    xw = jax.random.normal(key, (4096, 1024))
    sc = jnp.ones((1024,))
    _row("kernel/rmsnorm_pallas", timeit(lambda a, s: ops.rmsnorm(a, s), xw, sc))
    _row("kernel/rmsnorm_ref", timeit(jax.jit(ref.rmsnorm_ref), xw, sc))


def bench_roofline_table(steps):
    """Emit §Roofline rows from the dry-run artifacts (single-pod)."""
    base = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "experiments", "dryrun")
    for path in sorted(glob.glob(os.path.join(base, "*__16x16.json"))):
        d = json.load(open(path))
        rl = d["roofline"]
        _row(f"roofline/{d['arch']}/{d['shape']}", d["compile_s"] * 1e6,
             compute_s=f"{rl['compute_s']:.3g}",
             memory_s=f"{rl['memory_s']:.3g}",
             collective_s=f"{rl['collective_s']:.3g}",
             bottleneck=rl["bottleneck"],
             useful=f"{rl['useful_ratio']:.2f}")


def bench_norm_test_knobs(steps):
    """Beyond-paper knobs (DESIGN §7.4): test interval and EMA smoothing of
    T_k — overhead amortization vs schedule fidelity."""
    from repro.launch.train import TrainJob, run_training, summarize
    for tag, interval, ema in (("interval1", 1, 0.0), ("interval5", 5, 0.0),
                               ("interval1_ema0.7", 1, 0.7)):
        job = TrainJob(arch="llama3.2-1b", steps=10**9,
                       total_samples=steps * 32, seq_len=64,
                       base_global_batch=4, max_global_batch=64,
                       base_micro_batch=2, max_micro_batch=4, base_accum=2,
                       eta=0.15, step_impl="accum_norm",
                       test_interval=interval, ema=ema, eval_every=0)
        import time as _t
        t0 = _t.time()
        h = run_training(job)
        ss = summarize(h)
        _row(f"norm_test_knobs/{tag}", (_t.time() - t0) / max(ss["steps"], 1) * 1e6,
             steps=ss["steps"], avg_bsz=round(ss["avg_batch"], 1),
             loss=round(ss["best_loss"], 3),
             final_bsz=h["global_batch"][-1])


BENCHES = {
    "table1_microllama": bench_table1_microllama,
    "table2_tinyllama": bench_table2_tinyllama,
    "table3_openllama": bench_table3_openllama,
    "engine_cache": bench_engine_cache,
    "norm_test_overhead": bench_norm_test_overhead,
    "norm_test_knobs": bench_norm_test_knobs,
    "kernel_micro": bench_kernel_micro,
    "roofline_table": bench_roofline_table,
}


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    p.add_argument("--steps", type=int, default=40)
    args = p.parse_args(argv)
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        fn(args.steps)


if __name__ == "__main__":
    main()
