import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    CheckpointError, FLAT_PARAMS_META, flat_params_metadata, save_checkpoint,
    restore_checkpoint, restore_params, restore_params_flat, latest_step)
from repro.distributed.flatbuf import FlatParams
from repro.testing.faults import FaultRule, InjectedFault, inject


def test_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3),
                   "blocks": [{"a": jnp.ones((4,))}, {"a": jnp.zeros((4,))}]},
        "count": jnp.asarray(7, jnp.int32),
    }
    d = str(tmp_path)
    save_checkpoint(d, 42, tree, metadata={"note": "hi"})
    assert latest_step(d) == 42
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = restore_checkpoint(d, 42, like)
    assert meta["step"] == 42 and meta["note"] == "hi"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 restored, tree)


def test_latest_of_many(tmp_path):
    d = str(tmp_path)
    for s in (1, 5, 3):
        save_checkpoint(d, s, {"x": jnp.zeros(2)})
    assert latest_step(d) == 5


# ---------------------------------------- flat-resident interop (§10) ----

def _params_tree():
    key = jax.random.PRNGKey(3)
    return {"w": jax.random.normal(key, (37, 5)),
            "blocks": [{"a": jax.random.normal(jax.random.PRNGKey(4), (23,))},
                       {"a": jax.random.normal(jax.random.PRNGKey(5), (23,))}],
        "scale": jnp.asarray(1.5, jnp.float32)}


def test_flat_resident_checkpoint_restores_into_tree_job(tmp_path):
    """A flat-resident checkpoint (one bucket_bytes/shard_divisor) restores
    BIT-exactly into a tree-resident job via the recorded layout recipe."""
    tree = _params_tree()
    fp = FlatParams.from_tree(tree, bucket_bytes=256, shard_divisor=4)
    d = str(tmp_path)
    save_checkpoint(d, 7, {"params": fp.buffers, "opt": {"count": jnp.zeros((), jnp.int32)}},
                    metadata={FLAT_PARAMS_META: flat_params_metadata(fp.layout)})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = restore_params(d, 7, like)
    assert meta[FLAT_PARAMS_META] == {"bucket_bytes": 256, "shard_divisor": 4}
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_flat_resident_checkpoint_restores_across_bucket_sizes(tmp_path):
    """A flat-resident checkpoint restores bit-exactly into a flat-resident
    job on a DIFFERENT backend bucket size / mesh divisor: the reader
    rebuilds the writer's layout from metadata, unflattens, and re-packs
    at its own layout."""
    tree = _params_tree()
    writer = FlatParams.from_tree(tree, bucket_bytes=256, shard_divisor=4)
    d = str(tmp_path)
    save_checkpoint(d, 3, {"params": writer.buffers},
                    metadata={FLAT_PARAMS_META:
                              flat_params_metadata(writer.layout)})
    reader, _ = restore_params_flat(d, 3, jax.tree.map(jnp.zeros_like, tree),
                                    bucket_bytes=64, shard_divisor=2)
    assert reader.layout.bucket_bytes == 64
    assert reader.layout.shard_divisor == 2
    assert reader.layout.buffer_sizes != writer.layout.buffer_sizes
    want = FlatParams.from_tree(tree, bucket_bytes=64, shard_divisor=2)
    assert len(reader.buffers) == len(want.buffers)
    for a, b in zip(reader.buffers, want.buffers):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(reader.to_tree()), jax.tree.leaves(tree)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_tree_checkpoint_restores_into_flat_job(tmp_path):
    """The reverse hop: a tree-resident checkpoint loads into a
    flat-resident job (no flat metadata -> leaf-keyed restore + pack)."""
    tree = _params_tree()
    d = str(tmp_path)
    save_checkpoint(d, 11, {"params": tree})
    fp, meta = restore_params_flat(d, 11, jax.tree.map(jnp.zeros_like, tree),
                                   bucket_bytes=128, shard_divisor=3)
    assert FLAT_PARAMS_META not in meta
    for a, b in zip(jax.tree.leaves(fp.to_tree()), jax.tree.leaves(tree)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------- crash atomicity (§12) ----

def test_crash_before_commit_leaves_previous_checkpoint(tmp_path):
    """A writer dying between temp-write and rename leaves only temp litter:
    `latest_step` still names the previous complete pair, and the next
    successful save cleans the litter up."""
    d = str(tmp_path)
    save_checkpoint(d, 1, {"x": jnp.arange(4.0)})
    with inject(FaultRule(site="ckpt.save.before_commit")):
        with pytest.raises(InjectedFault):
            save_checkpoint(d, 2, {"x": jnp.arange(4.0) + 1})
    assert latest_step(d) == 1
    assert any(".tmp" in f for f in os.listdir(d))      # the litter
    restored, _ = restore_checkpoint(d, 1, {"x": jnp.zeros(4)})
    np.testing.assert_array_equal(restored["x"], np.arange(4.0))
    save_checkpoint(d, 3, {"x": jnp.arange(4.0) + 2})
    assert latest_step(d) == 3
    assert not any(".tmp" in f for f in os.listdir(d))  # litter cleaned


def test_lone_json_is_not_a_checkpoint(tmp_path):
    """`latest_step` requires the COMPLETE pair: a metadata file whose npz
    never landed (crash between the two renames) is invisible."""
    d = str(tmp_path)
    save_checkpoint(d, 4, {"x": jnp.zeros(2)})
    (tmp_path / "ckpt_00000009.json").write_text("{}")
    assert latest_step(d) == 4


def test_truncated_npz_raises_typed_error(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 6, {"x": jnp.arange(128.0)})
    with inject(FaultRule(site="ckpt.saved", action="truncate",
                          keep_bytes=40)):
        save_checkpoint(d, 7, {"x": jnp.arange(128.0)})
    assert latest_step(d) == 7       # pair exists; the tear is inside the npz
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        restore_checkpoint(d, 7, {"x": jnp.zeros(128)})
    restore_checkpoint(d, 6, {"x": jnp.zeros(128)})     # older pair intact


def test_missing_and_mismatched_entries_are_loud(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"x": jnp.zeros(3)})
    with pytest.raises(CheckpointError, match="does not exist"):
        restore_checkpoint(d, 99, {"x": jnp.zeros(3)})
    with pytest.raises(CheckpointError, match="no entry"):
        restore_checkpoint(d, 1, {"y": jnp.zeros(3)})
    with pytest.raises(CheckpointError, match="shape"):
        restore_checkpoint(d, 1, {"x": jnp.zeros(4)})
