import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (
    save_checkpoint, restore_checkpoint, latest_step)


def test_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3),
                   "blocks": [{"a": jnp.ones((4,))}, {"a": jnp.zeros((4,))}]},
        "count": jnp.asarray(7, jnp.int32),
    }
    d = str(tmp_path)
    save_checkpoint(d, 42, tree, metadata={"note": "hi"})
    assert latest_step(d) == 42
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = restore_checkpoint(d, 42, like)
    assert meta["step"] == 42 and meta["note"] == "hi"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 restored, tree)


def test_latest_of_many(tmp_path):
    d = str(tmp_path)
    for s in (1, 5, 3):
        save_checkpoint(d, s, {"x": jnp.zeros(2)})
    assert latest_step(d) == 5
