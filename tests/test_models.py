"""Per-architecture smoke tests: reduced variants, one forward/train step on
CPU, shapes + finiteness; decode == full-forward equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_smoke_config, get_config
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, init_adamw, adamw_update

KEY = jax.random.PRNGKey(0)


def make_smoke_batch(cfg, b=2, t=32):
    text_len = t - (cfg.frontend.num_prefix_tokens
                    if cfg.frontend.kind == "vision_stub" else 0)
    batch = {
        "tokens": jax.random.randint(KEY, (b, text_len), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, text_len), 0, cfg.vocab_size),
    }
    if cfg.frontend.kind == "vision_stub":
        batch["patch_embeds"] = jnp.ones(
            (b, cfg.frontend.num_prefix_tokens, cfg.d_model), cfg.act_dtype)
    if cfg.frontend.kind == "audio_stub":
        batch["frames"] = jnp.ones(
            (b, cfg.encoder.num_frames, cfg.d_model), cfg.act_dtype)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_smoke_batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss), arch
    logits = model.logits(params, batch)
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))
    # one full train step: grads finite, params change, loss finite after
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert jnp.all(jnp.isfinite(leaf)), arch
    opt = init_adamw(params)
    new_params, _, gnorm = adamw_update(params, grads, opt, AdamWConfig(), 1e-3)
    assert float(gnorm) > 0
    loss2, _ = model.loss(new_params, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if get_smoke_config(a).frontend.kind == "none"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    b, t = 2, 16
    tokens = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    full = model.logits(params, {"tokens": tokens, "labels": tokens})
    cache = model.init_cache(b, t)
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(t):
        lg, cache = step(params, cache, tokens[:, i], jnp.int32(i))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_ring_decode_long_context_mode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 8, ring=True)
    tok = jnp.zeros((2,), jnp.int32)
    if cfg.frontend.kind == "audio_stub":
        cache["cross_prefix"] = [jax.tree.map(jnp.ones_like, c)
                                 for c in cache["cross_prefix"]]
        cache["cross_scanned"] = [jax.tree.map(jnp.ones_like, c)
                                  for c in cache["cross_scanned"]]
    # position far beyond the ring length must still be finite
    lg, cache = model.decode_step(params, cache, tok, jnp.int32(37), ring=True)
    assert jnp.all(jnp.isfinite(lg)), arch


def test_scan_vs_unroll_identical():
    cfg = get_smoke_config("gemma2-27b")
    model_scan = build_model(cfg.replace(scan_layers=True, num_layers=4))
    model_unroll = build_model(cfg.replace(scan_layers=False, num_layers=4))
    params = model_scan.init(KEY)
    batch = make_smoke_batch(cfg)
    l1, _ = model_scan.loss(params, batch)
    l2, _ = model_unroll.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_chunked_attention_matches_direct():
    """The flash-style jnp q-chunked path must equal direct attention."""
    from repro.models import attention as A
    k1, k2, k3 = jax.random.split(KEY, 3)
    b, t, h, kvh, d = 2, 2048, 4, 2, 32
    q = jax.random.normal(k1, (b, t, h, d))
    k = jax.random.normal(k2, (b, t, kvh, d))
    v = jax.random.normal(k3, (b, t, kvh, d))
    out_chunk = A._sdpa_chunked(q, k, v, softcap=0.0, causal=True, window=0)
    mask = A.causal_mask(t, t)
    out_direct = A._sdpa(q, k, v, mask, 0.0)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_direct),
                               rtol=2e-5, atol=2e-5)


def test_full_config_param_counts():
    """Full (non-smoke) configs must match their model cards (DESIGN §4)."""
    expect = {
        "dbrx-132b": (131.6e9, 0.02),
        "deepseek-v2-236b": (235.6e9, 0.02),
        "gemma2-27b": (27.2e9, 0.02),
        "nemotron-4-15b": (15.6e9, 0.05),
        "phi3-mini-3.8b": (3.8e9, 0.05),
        "recurrentgemma-9b": (9.4e9, 0.05),
        "mamba2-370m": (0.37e9, 0.05),
        "llama3.2-1b": (1.24e9, 0.02),
        "whisper-base": (0.072e9, 0.1),
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got, want)


def test_moe_aux_loss_and_balance():
    from repro.models.moe import init_moe, moe_apply
    from repro.models.config import MoEConfig
    m = MoEConfig(num_experts=4, top_k=2, d_expert=32, capacity_factor=2.0)
    p = init_moe(jax.random.PRNGKey(1), 16, m, "swiglu", jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 16))
    out, aux = moe_apply(p, x, m)
    assert out.shape == x.shape
    assert float(aux) > 0  # switch aux loss >= coef * 1.0 at balance
