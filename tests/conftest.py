import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def run_subprocess(code: str, devices: int = 1, timeout: int = 560,
                   env_extra: dict | None = None) -> str:
    """Run a python snippet in a fresh process with a forced device count
    (keeps the main pytest process at 1 device, per the dry-run isolation
    rule).  `env_extra` overlays the environment — e.g. PYTHONHASHSEED for
    the hash-randomization determinism tests, REPRO_COORD_* for
    coordination geometry."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    if env_extra:
        env.update(env_extra)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{res.stdout}\n{res.stderr}")
    return res.stdout


@pytest.fixture
def subproc():
    return run_subprocess
