"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("shape", [(17,), (1024,), (257, 3), (8, 128),
                                   (1000, 33), (2, 3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sqdiff_norm_sweep(shape, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, shape).astype(dtype)
    y = jax.random.normal(k2, shape).astype(dtype)
    got = ops.sqdiff_norm(x, y)
    want = ref.sqdiff_norm_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3 if dtype == jnp.bfloat16 else 1e-5)


@given(n=st.integers(1, 5000))
@settings(max_examples=20, deadline=None)
def test_sqdiff_norm_property(n):
    x = jnp.arange(n, dtype=jnp.float32) / n
    got = float(ops.sqdiff_norm(x, jnp.zeros_like(x)))
    want = float(jnp.sum(x * x))
    assert abs(got - want) <= 1e-4 * max(want, 1.0)


@pytest.mark.parametrize("shape", [(100,), (1024,), (31, 67)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adamw_sweep(shape, dtype):
    keys = jax.random.split(KEY, 4)
    p = jax.random.normal(keys[0], shape).astype(dtype)
    g = jax.random.normal(keys[1], shape).astype(dtype)
    m = jax.random.normal(keys[2], shape).astype(jnp.float32)
    v = jnp.abs(jax.random.normal(keys[3], shape)).astype(jnp.float32)
    kw = dict(lr=3e-4, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
              c1=0.7, c2=0.4)
    got = ops.fused_adamw(p, g, m, v, **kw)
    want = ref.adamw_ref(p, g, m, v, **kw)
    for a, b in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-5)


@pytest.mark.parametrize("rows,d", [(1, 128), (37, 256), (200, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (rows, d)).astype(dtype)
    s = jax.random.normal(k2, (d,)).astype(dtype)
    got = ops.rmsnorm(x, s)
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("b,t,h,kvh,d,causal,window,softcap", [
    (2, 256, 4, 2, 64, True, 0, 0.0),
    (1, 512, 4, 4, 64, True, 128, 0.0),
    (2, 256, 8, 2, 32, True, 0, 50.0),       # gemma2-style softcap
    (1, 256, 2, 2, 64, False, 0, 0.0),        # encoder (bidirectional)
    (1, 384, 4, 1, 64, True, 256, 30.0),      # MQA + window + cap
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, t, h, kvh, d, causal, window, softcap, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, t, h, d)).astype(dtype)
    k = jax.random.normal(k2, (b, t, kvh, d)).astype(dtype)
    v = jax.random.normal(k3, (b, t, kvh, d)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=128, block_kv=128)
    kx = jnp.repeat(k, h // kvh, axis=2)
    vx = jnp.repeat(v, h // kvh, axis=2)
    want = ref.attention_ref(q, kx, vx, causal=causal, window=window,
                             softcap=softcap)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_matches_model_attention():
    """Kernel must agree with the model's own attention math end to end."""
    from repro.models.attention import _sdpa, causal_mask
    k1, k2, k3 = jax.random.split(KEY, 3)
    b, t, h, kvh, d = 2, 256, 8, 4, 64
    q = jax.random.normal(k1, (b, t, h, d))
    k = jax.random.normal(k2, (b, t, kvh, d))
    v = jax.random.normal(k3, (b, t, kvh, d))
    want = _sdpa(q, k, v, causal_mask(t, t), 0.0)
    got = ops.flash_attention(q, k, v, causal=True, block_q=128, block_kv=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
