"""The dry-run machinery itself: lower_combo produces a coherent record
(cost calibration, collectives, roofline terms) — run in a subprocess since
dryrun.py forces 512 host devices on import."""
import json
import pytest


def test_lower_combo_record(subproc):
    out = subproc("""
import json
from repro.launch.dryrun import lower_combo
compiled, rec = lower_combo("llama3.2-1b", "decode_32k", multi_pod=False)
rl = rec["roofline"]
assert rec["devices"] == 256 and rec["workers_J"] == 16
assert rl["flops"] > 0 and rl["hbm_bytes"] > 0
assert rl["bottleneck"] in ("compute", "memory", "collective")
assert rec["memory"]["argument_size_in_bytes"] > 0
# calibration present and monotone (depth-2 cost > depth-1 cost)
cal = rec["cost"]["calibration"]
assert cal["f2"] > cal["f1"] > 0 and cal["repeats"] == 16
print("DRYRUN_OK", json.dumps({k: rl[k] for k in ("bottleneck",)}))
""", devices=1, timeout=560)
    assert "DRYRUN_OK" in out


def test_roofline_parser_units():
    from repro.launch.roofline import (_shape_bytes, parse_collectives,
                                       wire_bytes, roofline_terms)
    assert _shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert _shape_bytes("(f32[8,8]{1,0}, u32[4]{0})") == 8 * 8 * 4 + 4 * 4
    hlo = """
  %ar = f32[1024]{0} all-reduce(%x), channel_id=1
  %ag.1 = bf16[64,64]{1,0} all-gather(%y), dimensions={0}
  %rs = f32[32]{0} reduce-scatter(f32[512]{0} %z), dimensions={0}
"""
    coll = parse_collectives(hlo)
    assert coll["all-reduce"]["count"] == 1
    assert coll["all-reduce"]["result_bytes"] == 4096
    assert coll["all-gather"]["result_bytes"] == 64 * 64 * 2
    assert coll["reduce-scatter"]["operand_bytes"] == 512 * 4 + 32 * 4 or \
        coll["reduce-scatter"]["operand_bytes"] >= 512 * 4
    wb = wire_bytes(coll)
    assert wb >= 2 * 4096 + 64 * 64 * 2
    rl = roofline_terms({"flops": 197e12, "bytes accessed": 819e9}, hlo, 0.0)
    assert abs(rl.compute_s - 1.0) < 1e-6
    assert abs(rl.memory_s - 1.0) < 1e-6
    assert rl.bottleneck in ("compute", "memory")


def test_model_flops_accounting():
    from repro.launch.roofline import model_flops_per_step
    from repro.configs import get_config
    from repro.configs.shapes import INPUT_SHAPES
    cfg = get_config("llama3.2-1b")
    f_train = model_flops_per_step(cfg, INPUT_SHAPES["train_4k"], 256)
    # 6 * N_active * tokens / devices
    expect = 6 * cfg.param_count(active_only=True) * 256 * 4096 / 256
    assert abs(f_train - expect) / expect < 1e-9
    f_dec = model_flops_per_step(cfg, INPUT_SHAPES["decode_32k"], 256)
    assert f_dec == 2 * cfg.param_count(active_only=True) * 128 / 256
    # MoE: active < total
    moe = get_config("dbrx-132b")
    assert moe.param_count(active_only=True) < 0.35 * moe.param_count()
