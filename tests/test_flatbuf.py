"""Flat gradient buffers & single-pass statistics (DESIGN §9): layout
round-trips, fused-stats agreement with the tree oracles, flat-vs-tree
train-step equality, and the launch-count (op-count) regression proxy."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.norm_test import tree_sqdiff, tree_sqnorm
from repro.distributed.flatbuf import FlatLayout, flatten_tree
from repro.kernels import ops, ref, resolve_interpret
from repro.optim.adamw import (
    AdamWConfig, init_adamw, init_adamw_flat, adamw_update, adamw_update_flat,
    flat_opt_state, unflat_opt_state)

KEY = jax.random.PRNGKey(11)


def _mixed_tree():
    """Mixed-dtype, odd-shape pytree incl. scalar and >bucket-size leaf."""
    return {
        "a": jnp.arange(17, dtype=jnp.float32),
        "nested": {"b": jnp.ones((3, 5), jnp.bfloat16),
                   "c": jnp.full((), 2.5, jnp.float32),
                   "d": jnp.arange(6, dtype=jnp.int32).reshape(2, 3)},
        "e": (jnp.linspace(0, 1, 257 * 3).reshape(257, 3).astype(jnp.bfloat16),
              jnp.eye(9, 7, dtype=jnp.float32)),
    }


def _randlike(seed, tree):
    leaves, td = jax.tree.flatten(tree)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return td.unflatten([jax.random.normal(k, l.shape).astype(l.dtype)
                         for k, l in zip(keys, leaves)])


# ------------------------------------------------------------ layout ----

def test_roundtrip_bit_exact_mixed_dtypes():
    tree = _mixed_tree()
    layout, buffers = flatten_tree(tree)
    # dtype-homogeneous buffers, one per dtype here (all under bucket size)
    assert {str(d) for d in layout.buffer_dtypes} == \
        {"float32", "bfloat16", "int32"}
    back = layout.unflatten(buffers)
    for want, got in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert want.dtype == got.dtype and want.shape == got.shape
        assert bool(jnp.all(want == got))     # bit-exact, no casts


def test_bucketing_splits_groups_and_respects_leaf_boundaries():
    tree = {f"w{i}": jnp.zeros((1000,), jnp.float32) for i in range(64)}
    layout = FlatLayout.from_tree(tree, bucket_bytes=16000)   # 4000 elems
    assert layout.num_buffers > 1
    assert sum(layout.buffer_sizes) == 64_000
    for slot in layout.slots:                 # leaves never straddle buckets
        assert slot.offset + slot.size <= layout.buffer_sizes[slot.buffer_index]
    # an oversized leaf becomes its own bucket
    big = {"big": jnp.zeros((10_000,)), "small": jnp.zeros((10,))}
    lay2 = FlatLayout.from_tree(big, bucket_bytes=4000)
    assert lay2.num_buffers == 2


def test_flatten_congruent_tree_through_param_layout():
    """f32 grads of a mixed-dtype param tree pack through the same slots."""
    params = {"p16": jnp.ones((8, 4), jnp.bfloat16), "p32": jnp.ones((5,))}
    layout = FlatLayout.from_tree(params)
    grads = jax.tree.map(lambda x: jnp.ones(x.shape, jnp.float32), params)
    bufs = layout.flatten(grads)
    assert all(b.dtype == jnp.float32 for b in bufs)
    back = layout.unflatten(bufs)
    assert jax.tree.leaves(back)[0].dtype == jnp.float32


def test_empty_tree_layout():
    """Zero-leaf trees: a valid (degenerate) layout with no buffers."""
    layout, bufs = flatten_tree({})
    assert layout.num_buffers == 0 and layout.num_leaves == 0
    assert bufs == [] and layout.zeros() == []
    assert layout.unflatten([]) == {}


def test_size0_leaves_roundtrip():
    """Size-0 leaves round trip; a dtype group holding ONLY size-0 leaves
    still owns a real (0-sized) bucket instead of a dangling slot."""
    tree = {"data": jnp.arange(5, dtype=jnp.float32),
            "empty": jnp.zeros((0,), jnp.float32),
            "empty2d": jnp.zeros((0, 3), jnp.float32),
            "ints": jnp.zeros((0,), jnp.int32)}      # all-empty int32 group
    layout, bufs = flatten_tree(tree)
    assert layout.num_buffers == 2                   # f32 bucket + 0-size i32
    assert 0 in layout.buffer_sizes
    assert all(s.buffer_index < layout.num_buffers for s in layout.slots)
    back = layout.unflatten(bufs)
    for want, got in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert want.dtype == got.dtype and want.shape == got.shape
        assert bool(jnp.all(want == got))


def test_single_oversized_leaf_is_own_bucket():
    """One leaf above bucket_bytes in a single-leaf tree: exactly one bucket
    of exactly the leaf's size (plus shard padding when requested)."""
    tree = {"big": jnp.zeros((5001,), jnp.float32)}
    layout = FlatLayout.from_tree(tree, bucket_bytes=4000)   # 1000-elem target
    assert layout.num_buffers == 1
    assert layout.buffer_sizes == (5001,) and layout.buffer_pads == (0,)
    lay8 = FlatLayout.from_tree(tree, bucket_bytes=4000, shard_divisor=8)
    assert lay8.buffer_sizes == (5008,) and lay8.buffer_pads == (7,)


def test_shard_divisor_padding_roundtrip():
    """Mesh-divisible bucket padding: every bucket size divides J, the pad
    is zero-filled on flatten, never referenced by a slot, and the
    flatten→unflatten round trip stays bit-exact."""
    tree = {"a": jnp.arange(17, dtype=jnp.float32),
            "b": jnp.linspace(-1, 1, 23).astype(jnp.float32),
            "c": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "d": jnp.ones((3, 5), jnp.bfloat16)}
    layout, bufs = flatten_tree(tree, bucket_bytes=64, shard_divisor=4)
    assert all(n % 4 == 0 for n in layout.buffer_sizes)
    assert sum(layout.buffer_pads) > 0               # padding actually occurred
    for buf, pad, size in zip(bufs, layout.buffer_pads, layout.buffer_sizes):
        assert buf.size == size
        if pad:
            assert bool(jnp.all(buf[size - pad:] == 0))   # zero-filled tail
    for s in layout.slots:                           # slots never touch the pad
        bi = s.buffer_index
        assert s.offset + s.size <= layout.buffer_sizes[bi] - layout.buffer_pads[bi]
    back = layout.unflatten(bufs)
    for want, got in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert want.dtype == got.dtype and want.shape == got.shape
        assert bool(jnp.all(want == got))            # bit-exact through the pad
    # moment state built at the same divisor matches the padded bucketing
    flat = init_adamw_flat(tree, shard_divisor=4)
    default_layout = FlatLayout.from_tree(tree, shard_divisor=4)
    assert tuple(b.size for b in flat["m"]) == default_layout.buffer_sizes
    assert all(n % 4 == 0 for n in default_layout.buffer_sizes)


def test_adamw_flat_padded_matches_tree():
    """Shard padding is inert end-to-end: the padded flat AdamW equals the
    tree update, and the pad region of the moments stays zero."""
    params = {"w": jax.random.normal(KEY, (37,)),
              "b": jax.random.normal(jax.random.PRNGKey(3), (10,))}
    grads = jax.tree.map(lambda x: x * 0.05 + 0.01, params)
    cfg = AdamWConfig()
    layout = FlatLayout.from_tree(params, shard_divisor=16)
    assert sum(layout.buffer_pads) > 0
    st = init_adamw(params)
    p1, s1, gn1 = adamw_update(params, grads, st, cfg, 1e-3)
    p2, s2, gn2, _ = adamw_update_flat(
        params, grads, flat_opt_state(params, st, shard_divisor=16), cfg,
        1e-3, layout=layout)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(gn1), float(gn2), rtol=1e-6)
    for mv in ("m", "v"):
        for buf, pad, size in zip(s2[mv], layout.buffer_pads,
                                  layout.buffer_sizes):
            if pad:
                assert bool(jnp.all(buf[size - pad:] == 0))


def test_layout_validation_errors():
    layout = FlatLayout.from_tree({"a": jnp.zeros((4,)), "b": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        layout.flatten({"a": jnp.zeros((4,))})            # wrong leaf count
    with pytest.raises(ValueError):
        layout.flatten({"a": jnp.zeros((5,)), "b": jnp.zeros((2,))})  # shape
    with pytest.raises(ValueError):
        layout.unflatten([jnp.zeros((7,))])               # wrong buffers


def test_pack_cotangents_keeps_f32_through_low_precision_layout():
    """The manual unflatten adjoint must NOT downcast: f32-accumulated
    gradients of bf16 params transpose through the bf16 layout's slots
    into f32 buffers bit-identical to `flatten` of the same f32 tree
    (the dtype-strict jax.vjp route would have quantized them to bf16)."""
    params = {"a": jnp.ones((9, 3), jnp.bfloat16), "b": jnp.ones((7,))}
    layout = FlatLayout.from_tree(params, shard_divisor=4)
    g32 = _randlike(0, jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params))
    got = layout.pack_cotangents(g32)
    want = layout.flatten(g32)
    for a, b in zip(got, want):
        assert a.dtype == jnp.float32
        assert bool(jnp.all(a == b))
    with pytest.raises(ValueError):
        layout.pack_cotangents({"a": jnp.zeros((9, 3))})   # wrong leaf count


# ------------------------------------------------------ fused stats ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_stats_kernel_matches_tree_oracles(dtype):
    """One-read (Σ(x−y)², Σy²) == tree_sqdiff + tree_sqnorm to 1e-5."""
    tree = {"a": jnp.zeros((300, 7)), "b": jnp.zeros((129,)),
            "c": jnp.zeros((2, 3, 5))}
    x = jax.tree.map(lambda l: l.astype(dtype), _randlike(0, tree))
    y = jax.tree.map(lambda l: l.astype(dtype), _randlike(1, tree))
    layout = FlatLayout.from_tree(x)
    xb, yb = layout.flatten(x), layout.flatten(y)
    tol = 2e-3 if dtype == jnp.bfloat16 else 1e-5
    d = q = 0.0
    for a, b in zip(xb, yb):
        dd, qq = ops.fused_stats(a, b)      # Pallas (interpret on CPU)
        d += float(dd)
        q += float(qq)
    np.testing.assert_allclose(d, float(tree_sqdiff(x, y)), rtol=tol)
    np.testing.assert_allclose(q, float(tree_sqnorm(y)), rtol=tol)


def test_stats_flat_dispatch_matches_ref():
    x = jax.random.normal(KEY, (1000,))
    y = jax.random.normal(jax.random.PRNGKey(1), (1000,))
    got = ops.stats_flat(x, y)               # CPU: fused-jnp reference
    want = ref.fused_stats_ref(x, y)
    for a, b in zip(got, want):
        np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_fused_adamw_stats_kernel_matches_ref():
    ks = jax.random.split(KEY, 4)
    p = jax.random.normal(ks[0], (700,))
    g = jax.random.normal(ks[1], (700,))
    m = jax.random.normal(ks[2], (700,))
    v = jnp.abs(jax.random.normal(ks[3], (700,)))
    kw = dict(lr=3e-4, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
              c1=0.7, c2=0.4, clip_scale=0.37)
    got = ops.fused_adamw_stats(p, g, m, v, **kw)
    want = ref.adamw_stats_ref(p, g, m, v, **kw)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    # the Σg² byproduct is of the RAW (pre-clip) gradient
    np.testing.assert_allclose(float(got[3]), float(jnp.sum(g * g)), rtol=1e-5)


# ------------------------------------------------------- flat adamw ----

@pytest.mark.parametrize("grad_clip", [1.0, 0.0])
def test_adamw_flat_matches_tree(grad_clip):
    params = {"w1": jax.random.normal(KEY, (64, 33)),
              "b": jax.random.normal(KEY, (65,)),
              "w2": jax.random.normal(jax.random.PRNGKey(1), (200, 3))}
    grads = jax.tree.map(lambda x: x * 0.02 + 0.1, params)
    cfg = AdamWConfig(grad_clip=grad_clip)
    st = init_adamw(params)
    st["m"] = jax.tree.map(lambda x: x * 0.5, grads)
    st["v"] = jax.tree.map(lambda x: jnp.abs(x) * 0.2, grads)
    st["count"] = jnp.asarray(5, jnp.int32)
    p1, s1, gn1 = adamw_update(params, grads, st, cfg, 1e-3)
    p2, s2, gn2, gsq2 = adamw_update_flat(
        params, grads, flat_opt_state(params, st), cfg, 1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(gn1), float(gn2), rtol=1e-6)
    np.testing.assert_allclose(float(gsq2), float(tree_sqnorm(grads)),
                               rtol=1e-5)
    s2_tree = unflat_opt_state(params, s2)
    for a, b in zip(jax.tree.leaves(s1["m"]), jax.tree.leaves(s2_tree["m"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
    assert int(s2["count"]) == 6


def test_flat_opt_state_roundtrip():
    params = {"a": jnp.ones((10, 3), jnp.bfloat16), "b": jnp.ones((7,))}
    st = init_adamw(params)
    back = unflat_opt_state(params, flat_opt_state(params, st))
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and bool(jnp.all(a == b))
    flat = init_adamw_flat(params)
    assert all(b.dtype == jnp.float32 for b in flat["m"] + flat["v"])


# ------------------------------------------- step-level equivalence ----

def _tiny_step_setup():
    from repro.compat import set_mesh
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.launch.mesh import make_host_mesh
    from repro.data.pipeline import MarkovTokens, make_batch
    from repro.core.schedule import BatchPlan
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    plan = BatchPlan(global_batch=4, micro_batch=2, accum_steps=2, workers=1)
    batch = jax.tree.map(jnp.asarray, make_batch(src, 0, plan, 16))
    return model, mesh, batch, set_mesh


@pytest.mark.parametrize("step_impl", ["fsdp_norm", "accum_norm"])
def test_flat_vs_tree_step_metrics_equal(step_impl):
    """Acceptance: identical (≤1e-5) loss, var_l1, grad_sqnorm and updated
    params on both FSDP-Norm and ACCUM-NORM steps."""
    from repro.distributed.train_step import (
        make_fsdp_norm_step, make_accum_norm_step)
    model, mesh, batch, set_mesh = _tiny_step_setup()
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    make = (make_fsdp_norm_step if step_impl == "fsdp_norm"
            else make_accum_norm_step)
    res = {}
    for stats_impl in ("tree", "flat"):
        params = model.init(jax.random.PRNGKey(0))
        opt = (init_adamw_flat(params) if stats_impl == "flat"
               else init_adamw(params))
        wrap, _, _ = make(model, AdamWConfig(), mesh, stats_impl=stats_impl,
                          params_like=params)
        with set_mesh(mesh):
            p, o, m = wrap(sds)(params, opt, batch, jnp.float32(1e-3))
        res[stats_impl] = (p, m)
    for k in ("loss", "var_l1", "grad_sqnorm", "grad_norm"):
        np.testing.assert_allclose(
            float(res["tree"][1][k]), float(res["flat"][1][k]),
            rtol=1e-5, atol=1e-8, err_msg=k)
    for a, b in zip(jax.tree.leaves(res["tree"][0]),
                    jax.tree.leaves(res["flat"][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_stats_impl_validation():
    from repro.distributed.train_step import (
        make_fsdp_norm_step, make_accum_norm_step)
    model, mesh, _, _ = _tiny_step_setup()
    with pytest.raises(ValueError):
        make_fsdp_norm_step(model, AdamWConfig(), mesh, stats_impl="bogus")
    with pytest.raises(ValueError):
        make_fsdp_norm_step(model, AdamWConfig(), mesh, stats_impl="flat",
                            variance_impl="paper")
    with pytest.raises(ValueError):
        make_accum_norm_step(model, AdamWConfig(), mesh, stats_impl="nope")


# --------------------------------------------- launch-count proxy ----

def test_flat_tail_op_count_scales_with_buckets_not_leaves():
    """The regression the flat path exists to prevent: the statistics tail
    must issue O(buckets) reductions, not O(leaves)."""
    tree = {f"w{i}": jnp.ones((100,)) for i in range(40)}
    layout = FlatLayout.from_tree(tree)     # 40 leaves -> 1 bucket
    assert layout.num_buffers == 1
    xb, yb = layout.flatten(tree), layout.flatten(tree)

    def count_reduce(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            n += str(eqn.primitive).startswith("reduce")
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    n += count_reduce(sub.jaxpr)
        return n

    tree_jaxpr = jax.make_jaxpr(
        lambda a, b: (tree_sqdiff(a, b), tree_sqnorm(b)))(tree, tree)
    flat_jaxpr = jax.make_jaxpr(
        lambda a, b: ops.stats_flat(a[0], b[0]))(xb, yb)
    n_tree = count_reduce(tree_jaxpr.jaxpr)
    n_flat = count_reduce(flat_jaxpr.jaxpr)
    assert n_tree >= 2 * 40                  # two reductions per leaf
    assert n_flat <= 2 * layout.num_buffers  # two per bucket


@pytest.mark.parametrize("step_impl,stats_impl,params_impl,expected", [
    # flat STATS on tree-resident params (DESIGN §9): FSDP-Norm packs g_j,
    # the mean gradient g, and the params (3); ACCUM-NORM packs g and the
    # params (2).  The old tail packed g twice — THE double-pack regression.
    ("fsdp_norm", "flat", "tree", 3),
    ("accum_norm", "flat", "tree", 2),
    # flat-RESIDENT params (DESIGN §10): gradients are born flat through
    # `unflatten_for_grad`, params never leave buffer form — the
    # steady-state step performs ZERO flatten packs.
    ("fsdp_norm", "flat", "flat", 0),
    ("accum_norm", "flat", "flat", 0),
    # tree-oracle tail over flat-resident params: the one pack is the
    # updated param tree re-entering residency.
    ("fsdp_norm", "tree", "flat", 1),
    ("accum_norm", "tree", "flat", 1),
])
def test_step_pack_count(step_impl, stats_impl, params_impl, expected):
    """THE pack-count regression guard: tracing one step must show exactly
    the pack eqns its residency combination requires — 3/2 for the
    flat-stats path (mean gradient packed exactly once), and ZERO for the
    flat-resident steady state (so neither the PR 3 double-pack bug class
    nor a regression to re-packing born-flat gradients can recur).

    Counted from the traced jaxpr's `repro_layout_marker` eqns
    (`repro.analysis.count_layout_ops`) — unlike the removed
    Python-call proxy, the eqn count holds THROUGH a jit
    boundary, so the same assertion also covers the jitted step (and the
    full stats×params×local-SGD matrix, including the unflatten/adjoint
    counts, is frozen in `analysis.invariants.EXPECTED_LAYOUT_COUNTS`)."""
    from repro.analysis import count_layout_ops
    from repro.distributed.train_step import (
        make_fsdp_norm_step, make_accum_norm_step)
    model, mesh, batch, set_mesh = _tiny_step_setup()
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    make = (make_fsdp_norm_step if step_impl == "fsdp_norm"
            else make_accum_norm_step)
    params = model.init(jax.random.PRNGKey(0))
    wrap, _, _ = make(model, AdamWConfig(), mesh, stats_impl=stats_impl,
                      params_impl=params_impl, params_like=params)
    opt = (init_adamw_flat(params, layout=wrap.flat_layout)
           if stats_impl == "flat" else init_adamw(params))
    if params_impl == "flat":
        # entering residency packs once, OUTSIDE the step — host-side cost,
        # paid once per run, not per step
        params = tuple(wrap.flat_layout.flatten(params))
    fn = wrap(sds)                       # the real JITTED step
    with set_mesh(mesh):
        ops_seen = count_layout_ops(fn, params, opt, batch, jnp.float32(1e-3))
    assert len(ops_seen["pack"]) == expected, (
        f"{step_impl}/{stats_impl}/{params_impl}: {len(ops_seen['pack'])} "
        f"pack eqns per step (expected {expected}): {ops_seen}")


def test_count_packs_alias_removed():
    """The PR 8 one-release transition is over: the Python-call proxy is
    gone from the module and its `__all__`; `count_layout_ops` (jaxpr-eqn
    counting) is the only pack counter."""
    import repro.distributed.flatbuf as fb
    assert not hasattr(fb, "count_packs")
    assert "count_packs" not in fb.__all__
    layout = FlatLayout.from_tree({"a": jnp.zeros((4,)), "b": jnp.zeros((2,))})
    from repro.analysis import count_layout_ops
    got = count_layout_ops(
        lambda t: layout.flatten(t),
        {"a": jnp.zeros((4,)), "b": jnp.zeros((2,))})
    assert got["pack"] == [2]


def test_layout_markers_visible_inside_jit():
    """The reason the proxy was replaced: pack/unflatten events inside an
    already-jitted callable are invisible to the Python-call counter but
    present as marker eqns in the traced jaxpr."""
    from repro.analysis import count_layout_ops
    tree = {"a": jnp.ones((5,)), "b": jnp.ones((3, 2))}
    layout = FlatLayout.from_tree(tree)
    jitted = jax.jit(lambda t: layout.unflatten(layout.flatten(t)))
    got = count_layout_ops(jitted, tree)
    assert len(got["pack"]) == 1 and len(got["unflatten"]) == 1
    # the adjoint pack of a flat-resident gradient is its own kind
    bufs = tuple(layout.flatten(tree))
    grad_fn = jax.grad(lambda bs: sum(
        jnp.sum(x) for x in jax.tree.leaves(layout.unflatten_for_grad(bs))))
    got = count_layout_ops(jax.jit(grad_fn), bufs)
    assert len(got["adjoint"]) == 1 and len(got["pack"]) == 0


def test_flat_moments_sharded_over_data_axes(subproc):
    """Acceptance: with a 2-device data axis the flat moment buffers carry
    data-axis PartitionSpecs (not P()) on BOTH step impls, and per-device
    optimizer-state bytes are exactly half the replicated footprint."""
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.distributed.train_step import (
    make_fsdp_norm_step, make_accum_norm_step)
from repro.optim.adamw import AdamWConfig, init_adamw_flat
from repro.data.pipeline import MarkovTokens, make_batch
from repro.core.schedule import BatchPlan

cfg = get_smoke_config("llama3.2-1b")
model = build_model(cfg)
mesh = make_host_mesh(data=2, model=1)
src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
plan = BatchPlan(global_batch=8, micro_batch=2, accum_steps=2, workers=2)
batch = jax.tree.map(jnp.asarray, make_batch(src, 0, plan, 16))
sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
for make in (make_fsdp_norm_step, make_accum_norm_step):
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw_flat(params, shard_divisor=2)
    wrap, _, o_specs = make(model, AdamWConfig(), mesh, stats_impl="flat",
                            params_like=params)
    with set_mesh(mesh):
        _, o, _ = wrap(sds)(params, opt, batch, jnp.float32(1e-3))
    for spec in o_specs["m"] + o_specs["v"]:
        assert spec != P(), f"replicated moment spec: {spec}"
        first = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
        assert "data" in first, spec
    total = local = 0
    for buf in o["m"] + o["v"]:
        assert buf.size % 2 == 0, buf.size        # J-divisible buckets
        dim0 = buf.sharding.spec[0] if buf.sharding.spec else None
        assert dim0 not in (None,), f"unsharded live buffer: {buf.sharding}"
        total += buf.size
        local += buf.addressable_shards[0].data.size
    assert local * 2 == total, (local, total)     # ~Jx memory saving, J=2
print("SHARDED_FLAT_OK")
""", devices=2)
    assert "SHARDED_FLAT_OK" in out


# ------------------------------------------------- interpret default ----

def test_resolve_interpret_env_override(monkeypatch):
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert resolve_interpret(None) is True          # CPU container
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert resolve_interpret(None) is True
