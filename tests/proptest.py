"""Property-test shim: `hypothesis` when installed, a tiny seeded random-case
generator otherwise.

The fallback implements just the surface the suite uses —
``@given(x=st.integers(0, 9), ...)``, ``@settings(max_examples=N,
deadline=None)``, and the ``integers`` / ``floats`` / ``sampled_from``
strategies — by drawing `max_examples` pseudo-random cases from a
`numpy.random.Generator` seeded per test function name, so failures are
reproducible on a bare interpreter with no third-party deps.
"""

from __future__ import annotations

import math
import zlib

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Strategy:
        def sample(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            # mix log-uniform draws with the endpoints so huge ranges still
            # exercise small values and the boundaries (hypothesis-ish)
            r = rng.random()
            if r < 0.05:
                return self.lo
            if r < 0.10:
                return self.hi
            if r < 0.55 and self.hi - self.lo > 1000:
                span = math.log(self.hi - self.lo + 1)
                return self.lo + int(math.exp(rng.random() * span)) - 1
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats(_Strategy):
        def __init__(self, lo, hi, allow_nan=False):
            self.lo, self.hi = float(lo), float(hi)

        def sample(self, rng):
            r = rng.random()
            if r < 0.05:
                return self.lo
            if r < 0.10:
                return self.hi
            return self.lo + (self.hi - self.lo) * rng.random()

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng):
            return self.options[int(rng.integers(len(self.options)))]

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, **_):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

    st = _St()

    def settings(max_examples: int = 50, deadline=None, **_):
        def deco(fn):
            fn._proptest_max_examples = max_examples
            return fn
        return deco

    def given(**strategies_kw):
        def deco(fn):
            # NOT functools.wraps: pytest must see a zero-arg signature, not
            # the strategy params (it would resolve them as fixtures)
            def run(*args, **kwargs):
                n = getattr(fn, "_proptest_max_examples", 50)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    case = {k: s.sample(rng) for k, s in strategies_kw.items()}
                    try:
                        fn(*args, **case, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsified on case {i} (seed {seed}): {case}"
                        ) from e
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(run, attr, getattr(fn, attr))
            return run
        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]


# ================================================================ tests ====
# Gradient-equivalence property (DESIGN §10): for randomized pytrees,
# differentiating a loss THROUGH the flat buffers must equal packing the
# tree gradient — grad(loss ∘ unflatten) on buffers == flatten(grad(loss)),
# bit-compared per bucket.  This pins the pad-slice adjoint: the shard-pad
# tail of every born-flat gradient buffer is exactly zero, both through the
# explicit `unflatten_for_grad` VJP (one pack per bucket) and through
# JAX's native slice adjoint of plain `unflatten` (per-slot pad + add).

import numpy as _np

import jax as _jax
import jax.numpy as _jnp

from repro.distributed.flatbuf import FlatLayout as _FlatLayout


def _random_float_tree(seed: int, bucket_elems: int):
    """Randomized pytree: mixed f32/bf16 leaves, size-0 leaves (1-D and
    2-D), an oversized leaf (> bucket capacity, its own bucket), odd
    shapes.  Float-only: the tree is differentiated."""
    rng = _np.random.default_rng(seed)
    dtypes = (_jnp.float32, _jnp.bfloat16)
    tree = {}
    n = int(rng.integers(2, 7))
    for i in range(n):
        kind = int(rng.integers(0, 5))
        if kind == 0:
            shape = (0,) if rng.integers(2) else (0, 3)
        elif kind == 1:
            shape = (int(bucket_elems * rng.uniform(1.25, 2.5)),)  # oversized
        elif kind == 2:
            shape = ()                                             # scalar
        elif kind == 3:
            shape = (int(rng.integers(1, 8)), int(rng.integers(1, 8)))
        else:
            shape = (int(rng.integers(1, 4 * bucket_elems)),)
        dt = dtypes[int(rng.integers(len(dtypes)))]
        tree[f"w{i}"] = _jnp.asarray(
            rng.standard_normal(shape), _jnp.float32).astype(dt)
    return tree


def _leaf_losses(tree):
    """Nonlinear scalar loss with position-dependent cotangents (a uniform
    weight would let transposed/permuted adjoints slip through)."""
    total = _jnp.zeros((), _jnp.float32)
    for leaf in _jax.tree.leaves(tree):
        x = leaf.astype(_jnp.float32)
        w = (_jnp.arange(1, x.size + 1, dtype=_jnp.float32)
             .reshape(x.shape if x.shape else ()))
        total = total + _jnp.sum(_jnp.sin(x) * w)
    return total


def _denorm_zero(b):
    """Map -0.0 to +0.0 (the native pad+add adjoint may flip the sign of a
    zero cotangent; everything else must match bit-for-bit)."""
    return _jnp.where(b == 0, _jnp.zeros_like(b), b)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100_000),
       bucket_bytes=st.sampled_from([64, 256, 4096]),
       divisor=st.sampled_from([1, 2, 4, 7]))
def test_grads_born_flat_equal_packed_tree_grads(seed, bucket_bytes, divisor):
    tree = _random_float_tree(seed, max(1, bucket_bytes // 4))
    layout = _FlatLayout.from_tree(tree, bucket_bytes=bucket_bytes,
                                   shard_divisor=divisor)
    bufs = tuple(layout.flatten(tree))

    want = layout.flatten(_jax.grad(_leaf_losses)(tree))
    got_custom = _jax.grad(
        lambda b: _leaf_losses(layout.unflatten_for_grad(b)))(bufs)
    got_native = _jax.grad(
        lambda b: _leaf_losses(layout.unflatten(list(b))))(bufs)

    assert len(want) == len(got_custom) == len(got_native) == layout.num_buffers
    for i, (w, c, n) in enumerate(zip(want, got_custom, got_native)):
        assert w.dtype == c.dtype == n.dtype, (i, w.dtype, c.dtype, n.dtype)
        assert w.shape == c.shape == n.shape, (i, w.shape, c.shape, n.shape)
        # explicit pack adjoint: bit-exact against the packed tree gradient
        assert bool(_jnp.all(w == c)), f"buffer {i}: custom VJP diverged"
        # native pad+add adjoint: bit-exact up to the sign of zero
        assert bool(_jnp.all(_denorm_zero(w) == _denorm_zero(n))), \
            f"buffer {i}: native slice adjoint diverged"
        # the shard-pad tail of a born-flat gradient buffer is exactly zero
        pad = layout.buffer_pads[i]
        if pad:
            assert bool(_jnp.all(c[w.size - pad:] == 0)), f"buffer {i} pad"
            assert bool(_jnp.all(n[w.size - pad:] == 0)), f"buffer {i} pad"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100_000),
       bucket_bytes=st.sampled_from([64, 1024]),
       divisor=st.sampled_from([1, 3, 8]))
def test_unflatten_for_grad_forward_is_unflatten(seed, bucket_bytes, divisor):
    """The custom-vjp wrapper must not perturb the forward pass: its output
    is bit-identical to plain `unflatten` (and round-trips the tree)."""
    tree = _random_float_tree(seed, max(1, bucket_bytes // 4))
    layout = _FlatLayout.from_tree(tree, bucket_bytes=bucket_bytes,
                                   shard_divisor=divisor)
    bufs = tuple(layout.flatten(tree))
    via_grad = layout.unflatten_for_grad(bufs)
    plain = layout.unflatten(list(bufs))
    for a, b, orig in zip(_jax.tree.leaves(via_grad), _jax.tree.leaves(plain),
                          _jax.tree.leaves(tree)):
        assert a.dtype == b.dtype == orig.dtype
        assert a.shape == b.shape == orig.shape
        assert bool(_jnp.all(a == b)) and bool(_jnp.all(a == orig))
