"""Property-test shim: `hypothesis` when installed, a tiny seeded random-case
generator otherwise.

The fallback implements just the surface the suite uses —
``@given(x=st.integers(0, 9), ...)``, ``@settings(max_examples=N,
deadline=None)``, and the ``integers`` / ``floats`` / ``sampled_from``
strategies — by drawing `max_examples` pseudo-random cases from a
`numpy.random.Generator` seeded per test function name, so failures are
reproducible on a bare interpreter with no third-party deps.
"""

from __future__ import annotations

import math
import zlib

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Strategy:
        def sample(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            # mix log-uniform draws with the endpoints so huge ranges still
            # exercise small values and the boundaries (hypothesis-ish)
            r = rng.random()
            if r < 0.05:
                return self.lo
            if r < 0.10:
                return self.hi
            if r < 0.55 and self.hi - self.lo > 1000:
                span = math.log(self.hi - self.lo + 1)
                return self.lo + int(math.exp(rng.random() * span)) - 1
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats(_Strategy):
        def __init__(self, lo, hi, allow_nan=False):
            self.lo, self.hi = float(lo), float(hi)

        def sample(self, rng):
            r = rng.random()
            if r < 0.05:
                return self.lo
            if r < 0.10:
                return self.hi
            return self.lo + (self.hi - self.lo) * rng.random()

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng):
            return self.options[int(rng.integers(len(self.options)))]

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, **_):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

    st = _St()

    def settings(max_examples: int = 50, deadline=None, **_):
        def deco(fn):
            fn._proptest_max_examples = max_examples
            return fn
        return deco

    def given(**strategies_kw):
        def deco(fn):
            # NOT functools.wraps: pytest must see a zero-arg signature, not
            # the strategy params (it would resolve them as fixtures)
            def run(*args, **kwargs):
                n = getattr(fn, "_proptest_max_examples", 50)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    case = {k: s.sample(rng) for k, s in strategies_kw.items()}
                    try:
                        fn(*args, **case, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsified on case {i} (seed {seed}): {case}"
                        ) from e
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(run, attr, getattr(fn, attr))
            return run
        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
