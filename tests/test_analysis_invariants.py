"""Layer-1 (jaxpr invariant checker) tests: the checker must PASS the real
step matrix and FAIL planted defects — an extra pack in the step graph, a
donation XLA silently drops — plus the off-ladder rejection contract.

The fsdp_norm/accum_norm halves of the matrix are certified in
tests/test_train_equivalence.py next to the numerics they guard; this file
covers the local-SGD + serving remainder and the negative space.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    check_ladder_rejection, check_variant, count_layout_ops,
    donation_effective, run_invariant_checks)
from repro.analysis.invariants import LayoutCounts, StepVariant
from repro.distributed.flatbuf import FlatLayout


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _variant(fn, args, expected):
    return StepVariant(name="planted", fn=fn, args=args, expected=expected,
                       spec_prefix=[], flat_groups=[])


def test_matrix_remainder_local_sgd_and_serving_clean():
    """local-SGD rounds + the serving decode step trace with zero invariant
    findings (fsdp/accum live in test_train_equivalence.py)."""
    combos = [("local_sgd", "tree", "tree"), ("local_sgd", "flat", "tree"),
              ("local_sgd", "flat", "flat"), ("serve_decode", "-", "-")]
    findings, checked = run_invariant_checks(combos=combos)
    active = [f for f in findings if not f.waived]
    assert not active, "\n".join(f.render() for f in active)
    assert len(checked["variants"]) == 4


def test_planted_extra_pack_is_flagged():
    """Acceptance criterion: a step graph that packs its tree one extra
    time (the PR 3 double-pack class) is flagged by the pack-count
    invariant — even though the repack is bit-identical and invisible to
    any numeric oracle."""
    tree = {"a": jnp.zeros((4,)), "b": jnp.zeros((2, 3))}
    layout = FlatLayout.from_tree(tree)

    def double_pack(t):
        bufs = layout.flatten(t)
        # the planted defect: a pointless unflatten/flatten round trip
        bufs = layout.flatten(layout.unflatten(list(bufs)))
        return layout.unflatten(list(bufs))

    v = _variant(jax.jit(double_pack), (_abstract(tree),),
                 expected=LayoutCounts(1, 1, 0))
    findings = check_variant(v)
    assert any(f.rule == "pack-count" for f in findings), findings
    msg = next(f.message for f in findings if f.rule == "pack-count")
    assert "packs=2" in msg and "packs=1" in msg

    # and the fixed graph passes the same check
    def single_pack(t):
        return layout.unflatten(list(layout.flatten(t)))

    ok = _variant(jax.jit(single_pack), (_abstract(tree),),
                  expected=LayoutCounts(1, 1, 0))
    assert not [f for f in check_variant(ok) if f.rule == "pack-count"]


def test_dropped_donation_is_flagged():
    """A donated input XLA cannot alias to any output (shape mismatch —
    the silent double-allocation class) must surface as a donation
    finding; a genuinely aliased donation must not."""
    import warnings
    # `a` is consumed (so it survives argument pruning) but its (3,) shape
    # matches no output — XLA cannot honour the donation
    dead_fn = jax.jit(lambda a, b: b * 2.0 + jnp.sum(a),
                      donate_argnums=(0,))
    args = (jax.ShapeDtypeStruct((3,), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # jax warns on the dropped donation
        attrs, dead = donation_effective(dead_fn, args)
        assert dead == [0]
        v = _variant(dead_fn, args, expected=LayoutCounts(0, 0, 0))
        findings = check_variant(v)
    assert any(f.rule == "donation" for f in findings), findings

    live_fn = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
    args = (jax.ShapeDtypeStruct((8,), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32))
    attrs, dead = donation_effective(live_fn, args)
    assert dead == [] and attrs[0].aliased
    v = _variant(live_fn, args, expected=LayoutCounts(0, 0, 0))
    assert not [f for f in check_variant(v) if f.rule == "donation"]


def test_off_ladder_batch_rejected_before_any_lowering():
    """Satellite fix: an off-ladder batch raises `LadderShapeError` from
    `get_step`/`trace_step` BEFORE the builder runs — zero fresh
    lowerings, zero cache entries, and an error that names the offending
    leaf and the valid rungs."""
    from repro.core.schedule import LadderShapeError, parse_ladder
    from repro.distributed.engine import BucketedEngine

    ladder = parse_ladder("2:1,2:2", workers=1)
    builds = []
    engine = BucketedEngine(lambda bl: builds.append(bl), ladder)
    off = {"tokens": jax.ShapeDtypeStruct((3, 2, 16), jnp.int32),
           "labels": jax.ShapeDtypeStruct((3, 2, 16), jnp.int32)}
    with pytest.raises(LadderShapeError) as e:
        engine.get_step(off)
    assert "labels" in str(e.value) and "(3, 2)" in str(e.value)
    assert "(1, 2)" in str(e.value)            # the rungs it should be on
    assert not builds and engine.stats.compiles == 0

    with pytest.raises(LadderShapeError):      # trace path guards too
        BucketedEngine(lambda bl: None, ladder, params_like={},
                       opt_like={}).trace_step(off)

    # the checker encodes the same contract
    assert check_ladder_rejection() == []


def test_count_layout_ops_sees_through_jit_and_grad():
    """The counter's core claim: marker eqns survive jit nesting and
    carry distinct kinds through differentiation."""
    tree = {"w": jnp.ones((6,))}
    layout = FlatLayout.from_tree(tree)
    inner = jax.jit(lambda t: layout.flatten(t))
    outer = jax.jit(lambda t: layout.unflatten(list(inner(t))))
    got = count_layout_ops(outer, _abstract(tree))
    assert (len(got["pack"]), len(got["unflatten"])) == (1, 1)
    assert got["pack"] == [layout.num_leaves]  # nleaves rides the eqn

    bufs = tuple(jnp.zeros((n,)) for n in layout.buffer_sizes)
    loss = lambda bs: jnp.sum(jax.tree.leaves(
        layout.unflatten_for_grad(bs))[0])
    got = count_layout_ops(jax.grad(loss), bufs)
    assert len(got["adjoint"]) == 1 and len(got["pack"]) == 0
