"""Crash-safe training (DESIGN §12): periodic checkpoints + `--resume`
reproduce the uninterrupted run BIT-identically — in-process, across a real
SIGKILL, in both parameter residencies — and a dead peer turns into a typed
`CoordinationError` with a checkpoint, not a hang.  The heaviest
multi-process kill scenarios run in the chaos tier (``REPRO_CHAOS=1``,
a dedicated CI job)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint.store import latest_step
from repro.launch.train import TrainJob, run_training

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

chaos = pytest.mark.skipif(os.environ.get("REPRO_CHAOS") != "1",
                           reason="chaos tier: set REPRO_CHAOS=1")


def _job_kw(**over):
    kw = dict(arch="llama3.2-1b", schedule="adaptive", steps=8,
              total_samples=100_000, seq_len=16, base_global_batch=4,
              max_global_batch=8, base_micro_batch=2, max_micro_batch=2,
              base_accum=2, eta=0.12, step_impl="accum_norm",
              eval_every=4, eval_batches=2)
    kw.update(over)
    return kw


def _assert_suffix_identical(resumed: dict, ref: dict, k: int):
    """The resumed run's history must equal the uninterrupted run's history
    from step k+1 on — EXACTLY (floats compared by ==, not tolerance)."""
    assert resumed["resumed_from"] == k
    assert resumed["loss"] == ref["loss"][k:]
    assert resumed["global_batch"] == ref["global_batch"][k:]
    assert resumed["samples"] == ref["samples"][k:]
    # eval points that fall in the resumed segment match too (NaN-safe)
    np.testing.assert_array_equal(np.asarray(resumed["val_loss"]),
                                  np.asarray(ref["val_loss"][k:]))


# ------------------------------------------------- in-process resume ----

@pytest.mark.parametrize("impl", ["tree", "flat"])
def test_resume_bit_identity_both_residencies(tmp_path, impl):
    """The acceptance bar, in-process: a run stopped at step 4 and resumed
    to step 8 produces the SAME losses/batches/params as one uninterrupted
    run — for tree-resident and flat-resident params."""
    kw = _job_kw(params_impl=impl, stats_impl=impl)
    ref = run_training(TrainJob(**kw))
    d = str(tmp_path / "ck")
    run_training(TrainJob(**{**kw, "steps": 4, "checkpoint_dir": d}))
    assert latest_step(d) == 4
    resumed = run_training(TrainJob(**{**kw, "checkpoint_dir": d,
                                       "resume": True}))
    _assert_suffix_identical(resumed, ref, 4)
    for a, b in zip(jax.tree.leaves(resumed["final_params"]),
                    jax.tree.leaves(ref["final_params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_with_empty_dir_starts_fresh(tmp_path):
    kw = _job_kw(steps=2, eval_every=0,
                 checkpoint_dir=str(tmp_path / "empty"), resume=True)
    h = run_training(TrainJob(**kw))
    assert h["resumed_from"] is None and len(h["loss"]) == 2
    assert latest_step(kw["checkpoint_dir"]) == 2      # final save happened


def test_resume_requires_checkpoint_dir():
    with pytest.raises(ValueError, match="checkpoint-dir"):
        run_training(TrainJob(**_job_kw(resume=True)))


def test_resume_config_mismatch_is_loud(tmp_path):
    d = str(tmp_path / "ck")
    run_training(TrainJob(**_job_kw(steps=2, eval_every=0,
                                    checkpoint_dir=d)))
    with pytest.raises(ValueError, match="config mismatch.*data_seed"):
        run_training(TrainJob(**_job_kw(checkpoint_dir=d, resume=True,
                                        data_seed=7)))


def test_periodic_checkpoints_written_and_log_appends(tmp_path):
    d = str(tmp_path / "ck")
    log = str(tmp_path / "train.csv")
    kw = _job_kw(steps=6, eval_every=0, checkpoint_dir=d, checkpoint_every=2,
                 log_path=log)
    run_training(TrainJob(**{**kw, "steps": 4}))
    # every multiple of checkpoint_every is on disk (4 is also the final)
    on_disk = {int(f[5:13]) for f in os.listdir(d) if f.endswith(".npz")}
    assert on_disk == {2, 4}
    lines_before = open(log).read().splitlines()
    run_training(TrainJob(**kw, resume=True))
    assert latest_step(d) == 6
    lines_after = open(log).read().splitlines()
    # appended (header once, no rewrite of the pre-crash rows)
    assert lines_after[:len(lines_before)] == lines_before
    assert len(lines_after) == 1 + 6   # header + one row per step


# ------------------------------------------------- SIGKILL + resume ----

_TRAIN_SNIPPET = """
import json, sys
from repro.launch.train import TrainJob, run_training
out_path = sys.argv[1]
h = run_training(TrainJob(**json.loads(sys.argv[2])))
json.dump({"loss": h["loss"], "global_batch": h["global_batch"],
           "samples": h["samples"],
           "val_loss": [v for v in h["val_loss"]],
           "resumed_from": h["resumed_from"]}, open(out_path, "w"))
print("DONE")
"""


def _train_subprocess(kw, out_path, faults=None, expect_sigkill=False,
                      timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_FAULTS", None)
    if faults:
        env["REPRO_FAULTS"] = json.dumps(faults)
    p = subprocess.run(
        [sys.executable, "-c", _TRAIN_SNIPPET, str(out_path), json.dumps(kw)],
        capture_output=True, text=True, env=env, timeout=timeout)
    if expect_sigkill:
        assert p.returncode == -9, (p.returncode, p.stderr)
        return None
    assert p.returncode == 0, f"train run failed:\n{p.stdout}\n{p.stderr}"
    return json.load(open(out_path))


def _kill_and_resume(tmp_path, impl, **over):
    """SIGKILL a run at step 6 (checkpoints every 2 -> last complete is 4),
    resume it, and demand bit-identity with an uninterrupted reference.
    Returns the checkpoint dir (for metadata assertions)."""
    d = str(tmp_path / "ck")
    kw = _job_kw(params_impl=impl, stats_impl=impl, eval_every=0, **over)
    ref = _train_subprocess(kw, tmp_path / "ref.json")
    victim = {**kw, "checkpoint_dir": d, "checkpoint_every": 2}
    _train_subprocess(victim, tmp_path / "victim.json",
                      faults=[{"site": "train.step", "at": 6,
                               "action": "die"}], expect_sigkill=True)
    assert latest_step(d) == 4      # step-6 work died before any save
    resumed = _train_subprocess({**victim, "resume": True},
                                tmp_path / "resumed.json")
    _assert_suffix_identical(resumed, ref, 4)
    return d


def test_sigkill_mid_run_resume_bit_identity(tmp_path):
    _kill_and_resume(tmp_path, "tree")


def test_sigkill_resume_bit_identity_with_predictor(tmp_path):
    """The same acceptance bar with the predictive GNS companion ON: the
    predictor state rides the checkpoint (populated gns_*/pred_* fields in
    the controller metadata) and the resumed run stays bit-identical —
    prediction observes the trajectory, never steers it.  base 32 of a
    64-ladder: the two-scale estimate is only valid once M·J is large, so
    the tracker provably initializes within the 8 steps."""
    d = _kill_and_resume(tmp_path, "tree", base_global_batch=32,
                         max_global_batch=64, predict=True, aot_warmup=True)
    assert latest_step(d) == 8
    ctrl = json.load(open(os.path.join(d, "ckpt_%08d.json" % 8)))["controller"]
    assert ctrl["gns_init"], ctrl
    assert ctrl["gns_g2"] > 0.0
    assert ctrl["pred_rung"] == 64          # the rung it actually sits on
    assert ctrl["pred_eta_steps"] == 0.0    # ...having already crossed


@chaos
def test_sigkill_mid_run_resume_bit_identity_flat(tmp_path):
    _kill_and_resume(tmp_path, "flat")


def test_sigkill_during_checkpoint_commit_keeps_previous(tmp_path):
    """A kill BETWEEN temp-write and rename (the torn-save window) leaves
    the previous checkpoint as latest; resume proceeds from it."""
    d = str(tmp_path / "ck")
    kw = _job_kw(steps=6, eval_every=0, checkpoint_dir=d,
                 checkpoint_every=2)
    _train_subprocess(kw, tmp_path / "victim.json",
                      faults=[{"site": "ckpt.save.before_commit", "at": 2,
                               "action": "die"}], expect_sigkill=True)
    # save #1 (step 2) committed; save #2 (step 4) died pre-rename
    assert latest_step(d) == 2
    resumed = _train_subprocess({**kw, "resume": True},
                                tmp_path / "resumed.json")
    assert resumed["resumed_from"] == 2 and len(resumed["loss"]) == 4
    assert latest_step(d) == 6


# --------------------------------------- dead peer: checkpoint + exit ----

_SURVIVOR_SNIPPET = """
import sys
from repro.launch.train import TrainJob, run_training
rank, coord_dir, ckdir = int(sys.argv[1]), sys.argv[2], sys.argv[3]
job = TrainJob(arch="llama3.2-1b", schedule="stagewise",
               stages=((0.5, 4), (0.5, 8)), steps=12, total_samples=48,
               seq_len=16, base_global_batch=4, max_global_batch=8,
               base_micro_batch=2, max_micro_batch=2, base_accum=2,
               step_impl="accum_norm", eval_every=0, aot_warmup=True,
               coord="file", coord_dir=coord_dir, coord_rank=rank,
               coord_world=2, coord_timeout=60.0,
               checkpoint_dir=(ckdir if rank == 0 else ""))
run_training(job)
print("DONE")
"""


@chaos
def test_dead_rank_surviving_rank_checkpoints_and_exits(tmp_path):
    """The acceptance bar for liveness: rank 1 is SIGKILLed at step 3; when
    rank 0 next needs the fleet (the rung-entry barrier of the stagewise
    4->8 increase at step 7) it must fail FAST with a `CoordinationError`
    naming rank 1 as dead — after writing a checkpoint of its intact state
    — instead of hanging out the full timeout."""
    coord = str(tmp_path / "coord")
    ck = str(tmp_path / "ck")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_FAULTS", None)
    env["REPRO_COORD_HEARTBEAT_S"] = "0.1"
    env["REPRO_COORD_DEAD_AFTER_S"] = "2.0"
    env_dead = dict(env)
    env_dead["REPRO_FAULTS"] = json.dumps(
        [{"site": "train.step", "at": 3, "action": "die"}])
    procs = [
        subprocess.Popen([sys.executable, "-c", _SURVIVOR_SNIPPET,
                          "0", coord, ck], stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE, text=True, env=env),
        subprocess.Popen([sys.executable, "-c", _SURVIVOR_SNIPPET,
                          "1", coord, ck], stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE, text=True, env=env_dead),
    ]
    out0, err0 = procs[0].communicate(timeout=420)
    out1, err1 = procs[1].communicate(timeout=60)
    assert procs[1].returncode == -9, (procs[1].returncode, err1)
    # the survivor exited with the TYPED error naming the dead rank...
    assert procs[0].returncode not in (0, None), (out0, err0)
    assert "CoordinationError" in err0, err0
    assert "dead ranks" in err0 and "[1]" in err0, err0
    # ...after checkpointing every step it completed alone (1..6: the
    # barrier it died on is the step-7 rung entry)
    assert latest_step(ck) == 6, os.listdir(ck)
