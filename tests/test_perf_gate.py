"""Bench gate (benchmarks/perf_gate.py): the committed-vs-fresh
BENCH_step.json comparison that bench-smoke runs on every PR."""

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))

from benchmarks.perf_gate import (
    DEFAULT_MULT, compare_step_times, gate_multiplier, run_gate)

REPO = pathlib.Path(__file__).parent.parent


def _grid(**cells):
    return {"step_per_bucket": {
        impl: {r: {"min_us": us} for r, us in rungs.items()}
        for impl, rungs in cells.items()}}


def test_identical_grids_pass():
    base = _grid(flat={"4": 100.0, "8": 200.0}, tree={"4": 110.0})
    assert compare_step_times(base, base, 8.0) == []


def test_regression_fails_with_ratio_in_message():
    base = _grid(flat={"4": 100.0})
    fresh = _grid(flat={"4": 900.0})
    fails = compare_step_times(fresh, base, 8.0)
    assert len(fails) == 1 and "9.0x" in fails[0]
    # under the multiplier: passes
    assert compare_step_times(_grid(flat={"4": 799.0}), base, 8.0) == []


def test_coverage_shrink_fails_but_growth_passes():
    base = _grid(flat={"4": 100.0, "8": 200.0})
    fresh = _grid(flat={"4": 100.0}, tree={"4": 90.0})   # dropped 8, added tree
    fails = compare_step_times(fresh, base, 8.0)
    assert len(fails) == 1 and "missing" in fails[0]


def test_empty_baseline_is_a_failure_not_a_pass():
    fails = compare_step_times(_grid(flat={"4": 1.0}), {}, 8.0)
    assert fails and "step_per_bucket" in fails[0]


def test_multiplier_precedence(monkeypatch):
    monkeypatch.delenv("BENCH_GATE_MULT", raising=False)
    assert gate_multiplier() == DEFAULT_MULT
    monkeypatch.setenv("BENCH_GATE_MULT", "3.5")
    assert gate_multiplier() == 3.5
    assert gate_multiplier(2.0) == 2.0          # CLI beats env


def test_committed_trajectory_self_gates(tmp_path, capsys):
    """The committed BENCH_step.json passes against itself (what a
    no-perf-change PR sees), and run_gate prints the verdict."""
    committed = REPO / "BENCH_step.json"
    assert committed.exists(), "BENCH_step.json must be committed"
    grid = json.load(open(committed)).get("step_per_bucket")
    assert grid, "committed trajectory must carry step_per_bucket"
    for impl in ("tree", "flat", "flat_resident"):
        assert impl in grid and grid[impl], impl
        assert all("min_us" in e for e in grid[impl].values())
    assert run_gate(str(committed), str(committed)) == []
    assert "perf gate PASS" in capsys.readouterr().out
