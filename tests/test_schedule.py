from repro.core.schedule import (
    BatchPlan, ConstantSchedule, StagewiseSchedule, accum_free_plan,
    quantize_to_ladder, round_plan)


def test_constant():
    plan = round_plan(64, 4, 4, 8, 4, 64)
    s = ConstantSchedule(plan)
    assert s.plan_for(0, 1000) == plan
    assert s.plan_for(999, 1000) == plan


def test_stagewise_boundaries():
    s = StagewiseSchedule(((0.025, 16), (0.025, 32), (0.95, 64)),
                          workers=4, micro_batch=1, max_micro_batch=8,
                          base_accum=4)
    total = 10_000
    assert s.plan_for(0, total).global_batch == 16
    assert s.plan_for(int(0.03 * total), total).global_batch == 32
    assert s.plan_for(int(0.9 * total), total).global_batch == 64
    assert s.plan_for(total - 1, total).global_batch == 64


def _plan(gb, micro, accum, workers=1):
    return BatchPlan(global_batch=gb, micro_batch=micro, accum_steps=accum,
                     workers=workers)


def test_stagewise_indivisible_stage_rounds_up_not_down():
    """Regression: `round_plan(batch, ..., max_global=batch)` SHRANK a stage
    whose prescribed size was not divisible by workers*micro_batch — the cap
    clamped the rounded-up plan back below the stage (10 with J=4, mb=2
    became 8 instead of the covering 16).  Stage plans must only round UP."""
    s = StagewiseSchedule(((0.5, 10), (0.5, 24)), workers=4, micro_batch=2,
                          max_micro_batch=2, base_accum=1)
    p0 = s.plan_for(0, 100)
    assert p0.global_batch >= 10, "stage size must never shrink"
    assert p0.global_batch == 16          # ceil(10 / (4*2)) * (4*2)
    p1 = s.plan_for(60, 100)
    assert p1.global_batch == 24


def test_stagewise_quantizes_onto_ladder():
    """With a ladder, stagewise emits RUNG plans: an off-ladder stage plan
    would die in the bucketed engine with LadderShapeError mid-training."""
    ladder = (_plan(8, 2, 1, 4), _plan(16, 2, 2, 4), _plan(32, 2, 4, 4))
    s = StagewiseSchedule(((0.5, 10), (0.5, 24)), workers=4, micro_batch=2,
                          max_micro_batch=2, base_accum=1, ladder=ladder)
    assert s.plan_for(0, 100) == ladder[1]     # 10 -> rounds up -> rung 16
    assert s.plan_for(60, 100) == ladder[2]    # 24 -> rung 32
    # a stage BELOW the ladder floor is NOT inflated to the floor rung: it
    # runs padded into the floor bucket, consuming only the prescribed
    # samples (the engine's standard sub-rung path)
    s2 = StagewiseSchedule(((0.5, 4), (0.5, 24)), workers=4, micro_batch=1,
                           max_micro_batch=2, base_accum=1, ladder=ladder)
    assert s2.plan_for(0, 100).global_batch == 4


def test_accum_free_plan():
    plan = _plan(32, 2, 4, workers=4)
    sub, repeats = accum_free_plan(plan)
    assert sub == _plan(8, 2, 1, workers=4)
    assert repeats == 4
    # exact sample conservation — the DESIGN §14 equivalence claim's basis
    assert sub.global_batch * repeats == plan.global_batch
    # already accumulation-free: identity
    sub1, rep1 = accum_free_plan(sub)
    assert sub1 == sub and rep1 == 1


def test_quantize_unsorted_ladder_finds_eligible_rungs():
    """Regression: the capped scan `break`s on the first rung above
    max_global, which silently skipped every later (eligible) rung when a
    programmatically-built ladder arrived unsorted — capacities are now
    sorted at entry."""
    unsorted = (_plan(64, 2, 32), _plan(4, 2, 2), _plan(16, 2, 8))
    # request 10 with cap 32: rung 16 is eligible but sits AFTER the 64 rung
    rung = quantize_to_ladder(10, unsorted, max_global=32)
    assert rung.global_batch == 16
    # uncapped: smallest covering rung, regardless of ladder order
    assert quantize_to_ladder(10, unsorted).global_batch == 16
    assert quantize_to_ladder(60, unsorted).global_batch == 64
    # everything above the cap -> smallest rung, not an arbitrary first one
    assert quantize_to_ladder(10, unsorted, max_global=2).global_batch == 4
