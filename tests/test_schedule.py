from repro.core.schedule import (
    BatchPlan, ConstantSchedule, StagewiseSchedule, quantize_to_ladder,
    round_plan)


def test_constant():
    plan = round_plan(64, 4, 4, 8, 4, 64)
    s = ConstantSchedule(plan)
    assert s.plan_for(0, 1000) == plan
    assert s.plan_for(999, 1000) == plan


def test_stagewise_boundaries():
    s = StagewiseSchedule(((0.025, 16), (0.025, 32), (0.95, 64)),
                          workers=4, micro_batch=1, max_micro_batch=8,
                          base_accum=4)
    total = 10_000
    assert s.plan_for(0, total).global_batch == 16
    assert s.plan_for(int(0.03 * total), total).global_batch == 32
    assert s.plan_for(int(0.9 * total), total).global_batch == 64
    assert s.plan_for(total - 1, total).global_batch == 64


def _plan(gb, micro, accum, workers=1):
    return BatchPlan(global_batch=gb, micro_batch=micro, accum_steps=accum,
                     workers=workers)


def test_quantize_unsorted_ladder_finds_eligible_rungs():
    """Regression: the capped scan `break`s on the first rung above
    max_global, which silently skipped every later (eligible) rung when a
    programmatically-built ladder arrived unsorted — capacities are now
    sorted at entry."""
    unsorted = (_plan(64, 2, 32), _plan(4, 2, 2), _plan(16, 2, 8))
    # request 10 with cap 32: rung 16 is eligible but sits AFTER the 64 rung
    rung = quantize_to_ladder(10, unsorted, max_global=32)
    assert rung.global_batch == 16
    # uncapped: smallest covering rung, regardless of ladder order
    assert quantize_to_ladder(10, unsorted).global_batch == 16
    assert quantize_to_ladder(60, unsorted).global_batch == 64
    # everything above the cap -> smallest rung, not an arbitrary first one
    assert quantize_to_ladder(10, unsorted, max_global=2).global_batch == 4
