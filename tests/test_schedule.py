from repro.core.schedule import (
    BatchPlan, ConstantSchedule, StagewiseSchedule, round_plan)


def test_constant():
    plan = round_plan(64, 4, 4, 8, 4, 64)
    s = ConstantSchedule(plan)
    assert s.plan_for(0, 1000) == plan
    assert s.plan_for(999, 1000) == plan


def test_stagewise_boundaries():
    s = StagewiseSchedule(((0.025, 16), (0.025, 32), (0.95, 64)),
                          workers=4, micro_batch=1, max_micro_batch=8,
                          base_accum=4)
    total = 10_000
    assert s.plan_for(0, total).global_batch == 16
    assert s.plan_for(int(0.03 * total), total).global_batch == 32
    assert s.plan_for(int(0.9 * total), total).global_batch == 64
    assert s.plan_for(total - 1, total).global_batch == 64
