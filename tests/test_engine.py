"""Bucketed step-compilation engine (DESIGN §8): compile-count regression,
padding exactness, ladder-quantization properties, end-to-end stats."""
import numpy as np
import pytest
from proptest import given, settings, st

import jax
import jax.numpy as jnp

from repro.core.controller import (
    ControllerConfig, init_controller, controller_update)
from repro.core.schedule import (
    BatchPlan, bucket_ladder, parse_ladder, quantize_to_ladder, round_plan)
from repro.data.pipeline import MarkovTokens, make_batch, pad_to_bucket
from repro.distributed.engine import BucketedEngine


# ------------------------------------------------------------- ladder ----

def test_ladder_covers_range_and_is_sorted():
    ladder = bucket_ladder(workers=8, micro_batch=4, max_micro_batch=8,
                           base_accum=16, base_global=256, max_global=8192)
    caps = [p.global_batch for p in ladder]
    assert caps == sorted(caps)
    assert caps[0] <= 256 * 2          # base rung near the base batch
    assert caps[-1] == round_plan(8192, 8, 4, 8, 16, 8192).global_batch
    for p in ladder:
        assert p.global_batch == p.workers * p.accum_steps * p.micro_batch
        assert p.micro_batch <= 8


def test_parse_ladder_and_rejects_nonincreasing():
    ladder = parse_ladder("2:1,2:2,4:2,4:4", workers=2)
    assert [p.global_batch for p in ladder] == [4, 8, 16, 32]
    with pytest.raises(ValueError):
        parse_ladder("4:4,2:2", workers=2)


@given(desired=st.integers(1, 10_000_000),
       workers=st.sampled_from([1, 2, 8]),
       micro=st.sampled_from([1, 2, 4]), max_micro=st.sampled_from([8, 16]),
       accum=st.sampled_from([1, 2, 16]),
       max_global=st.sampled_from([512, 8192]))
@settings(max_examples=200, deadline=None)
def test_quantize_never_shrinks_and_respects_max(desired, workers, micro,
                                                 max_micro, accum, max_global):
    base = workers * micro
    ladder = bucket_ladder(workers, micro, max_micro, accum, base, max_global)
    rung = quantize_to_ladder(desired, ladder, max_global)
    assert rung in ladder
    top = ladder[-1].global_batch
    # never shrinks: any request a rung can cover gets a covering rung
    assert rung.global_batch >= min(desired, max_global, top)
    # respects the cap: no rung exceeds max_global
    assert rung.global_batch <= max_global


# ------------------------------------------------------------ padding ----

def _plan(gb, micro, accum, workers=1):
    return BatchPlan(global_batch=gb, micro_batch=micro, accum_steps=accum,
                     workers=workers)


def test_pad_to_bucket_layout_and_mask():
    src = MarkovTokens(vocab_size=64, seed=0)
    plan = _plan(5, 1, 5)
    bucket = _plan(16, 2, 8)
    batch = make_batch(src, 0, plan, seq_len=8)
    padded = pad_to_bucket(batch, plan, bucket)
    assert padded["tokens"].shape == (8, 2, 8)
    flat_lab = padded["labels"].reshape(16, 8)
    flat_ref = batch["labels"].reshape(5, 8)
    np.testing.assert_array_equal(flat_lab[:5], flat_ref)
    assert (flat_lab[5:] == -1).all()          # padded slots fully masked
    # identical bucket shape -> no-op
    same = pad_to_bucket(batch, plan, _plan(5, 1, 5))
    assert same is batch


def test_padded_batch_identical_loss_and_grads():
    """The acceptance bar: padded vs unpadded batch produce the same loss and
    the same updated parameters to 1e-5 (accum_norm, 1-worker mesh)."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.launch.mesh import make_host_mesh
    from repro.distributed.train_step import make_accum_norm_step
    from repro.optim.adamw import AdamWConfig, init_adamw
    from repro.compat import set_mesh

    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    plan = _plan(6, 2, 3)                      # 6 real samples in 3 microbatches
    bucket = _plan(16, 2, 8)                   # 10 padded slots, 5 empty rows
    batch = make_batch(src, 0, plan, seq_len=16)
    padded = pad_to_bucket(batch, plan, bucket)

    outs = {}
    for tag, b in (("plain", batch), ("padded", padded)):
        params = model.init(jax.random.PRNGKey(0))   # fresh: steps donate args
        opt = init_adamw(params)
        wrap, _, _ = make_accum_norm_step(model, AdamWConfig(), mesh,
                                          params_like=params)
        fn = wrap(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.tree.map(jnp.asarray, b)))
        with set_mesh(mesh):
            p2, _, m = fn(params, opt, jax.tree.map(jnp.asarray, b),
                          jnp.float32(1e-3))
        outs[tag] = (p2, m)

    lp, lm = outs["plain"][1], outs["padded"][1]
    assert abs(float(lp["loss"]) - float(lm["loss"])) < 1e-5
    assert abs(float(lp["grad_sqnorm"]) - float(lm["grad_sqnorm"])) < 1e-4 * \
        max(float(lp["grad_sqnorm"]), 1.0)
    for a, b in zip(jax.tree.leaves(outs["plain"][0]),
                    jax.tree.leaves(outs["padded"][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


# ----------------------------------------------- compile-count caching ----

def test_one_trace_per_bucket_256_to_8192():
    """Regression for the tentpole claim: a simulated adaptive schedule that
    grows 256→8192 builds EXACTLY one step per ladder rung it visits; every
    other step is a cache hit."""
    cfg = ControllerConfig(eta=0.2, workers=8, base_micro_batch=4,
                           max_micro_batch=8, base_accum=8,
                           base_global_batch=256, max_global_batch=8192)
    ladder = bucket_ladder(cfg.workers, cfg.base_micro_batch,
                           cfg.max_micro_batch, cfg.base_accum,
                           cfg.base_global_batch, cfg.max_global_batch)
    cfg = ControllerConfig(**{**cfg.__dict__, "ladder": ladder})

    traces = []                      # one append per engine build == trace

    def counting_wrap(batch_like):
        key = tuple(sorted((k, tuple(v.shape)) for k, v in batch_like.items()))
        traces.append(key)
        return lambda *a: None

    engine = BucketedEngine(counting_wrap, ladder)
    src = MarkovTokens(vocab_size=32, seed=0)
    state = init_controller(cfg)
    # T_k ramp: forces progressive growth through every intermediate rung
    for step in range(60):
        plan = state.plan
        bucket = engine.bucket_for(plan.global_batch, cfg.max_global_batch)
        batch = pad_to_bucket(make_batch(src, step, plan, seq_len=4),
                              plan, bucket)
        engine.get_step(batch)
        engine.observe(plan, bucket)
        t_target = min(256 * 2 ** (step // 4), 8192) * 1.5
        state = controller_update(cfg, state, var_l1=t_target * cfg.eta**2,
                                  grad_sqnorm=1.0)

    assert state.plan.global_batch == 8192 and state.at_max
    visited = set(engine.stats.buckets_used)
    assert len(traces) == len(set(traces)) == len(visited), (
        traces, visited)
    assert engine.stats.compiles == len(visited)
    assert engine.stats.hits == engine.stats.steps - len(visited)
    # adaptive plans are ladder-quantized -> zero padding waste
    assert engine.stats.padding_waste == 0.0
    # the run climbed through multiple rungs, not just base+top
    assert len(visited) >= 3


def test_engine_warmup_precompiles_next_bucket():
    """AOT warmup lands the next rung in the cache: stepping into it later is
    a hit, not a fresh build."""
    ladder = parse_ladder("2:1,2:2,2:4", workers=1)
    builds = []

    def counting_wrap(batch_like):
        builds.append(tuple(v.shape for v in batch_like.values()))
        return lambda *a: None

    # fake jit object protocol for the AOT path: lower().compile()
    class FakeJitted:
        def lower(self, *a):
            return self

        def compile(self):
            return lambda *a: None

    def aot_wrap(batch_like):
        builds.append(tuple(v.shape for v in batch_like.values()))
        return FakeJitted()

    engine = BucketedEngine(aot_wrap, ladder, params_like={}, opt_like={},
                            aot_warmup=True)
    src = MarkovTokens(vocab_size=32, seed=0)
    plan = ladder[0]
    batch = make_batch(src, 0, plan, seq_len=4)
    engine.get_step(batch)
    engine.warmup(engine.next_bucket(plan), batch)
    engine.drain()
    assert engine.stats.warmups == 1 and len(builds) == 2
    # stepping into the warmed rung: served from cache, no third build
    plan2 = ladder[1]
    batch2 = pad_to_bucket(make_batch(src, 1, plan2, seq_len=4), plan2, plan2)
    before = engine.stats.hits
    engine.get_step(batch2)
    assert len(builds) == 2 and engine.stats.hits == before + 1


def test_engine_warmup_failure_counts_and_surfaces():
    """Warmup stats are counted on COMPLETION: a background compile that
    raises contributes to warmup_failures (never warmups/compiles),
    `get_step` falls back to a synchronous build, and `drain()` re-raises
    instead of swallowing the exception into a cache entry."""
    ladder = parse_ladder("2:1,2:2,2:4", workers=1)

    class ExplodingJitted:
        def lower(self, *a):
            raise RuntimeError("boom: AOT lowering failed")

    builds = []

    def wrap(batch_like):
        builds.append(1)
        return ExplodingJitted()

    engine = BucketedEngine(wrap, ladder, params_like={}, opt_like={},
                            aot_warmup=True)
    src = MarkovTokens(vocab_size=32, seed=0)
    plan = ladder[0]
    batch = make_batch(src, 0, plan, seq_len=4)
    engine.warmup(ladder[1], batch)
    with pytest.raises(RuntimeError, match="warmup compile"):
        engine.drain()
    assert engine.stats.warmups == 0 and engine.stats.compiles == 0
    assert engine.stats.warmup_failures == 1
    assert engine.stats.as_dict()["warmup_failures"] == 1

    # a failed warmup consumed by get_step: sync fallback, error kept for
    # drain, training itself not interrupted
    engine2 = BucketedEngine(wrap, ladder, params_like={}, opt_like={},
                             aot_warmup=True)
    engine2.warmup(ladder[1], batch)
    plan2 = ladder[1]
    batch2 = pad_to_bucket(make_batch(src, 1, plan2, seq_len=4), plan2, plan2)
    # get_step blocks on the pending future, swallows its failure into
    # warmup_failures, and falls back to a fresh sync build
    step = engine2.get_step(batch2)
    assert isinstance(step, ExplodingJitted)
    assert engine2.stats.warmup_failures == 1
    assert engine2.stats.compiles == 1          # the sync fallback build
    with pytest.raises(RuntimeError, match="warmup compile"):
        engine2.drain()
    engine2.drain()                    # errors were flushed by the raise
    assert engine2.stats.warmup_failures == 1


def test_warmup_failure_accounted_exactly_once_under_race():
    """Satellite bugfix: `drain` used to iterate a STALE snapshot of
    `_pending` while `get_step` popped and recorded the same future's
    failure — one background exception inflated `warmup_failures` to 2 and
    re-raised a handled error.  Accounting is now claim-based (whoever pops
    the key under the lock owns the outcome), so a drain racing a get_step
    against one deliberately failing warmup records EXACTLY one failure."""
    import threading
    import time as _time

    ladder = parse_ladder("2:1,2:2", workers=1)
    release = threading.Event()

    class BlockingExploder:
        def lower(self, *a):
            release.wait(timeout=30)
            raise RuntimeError("boom: deferred AOT failure")

    engine = BucketedEngine(lambda bl: BlockingExploder(), ladder,
                            params_like={}, opt_like={}, aot_warmup=True)
    src = MarkovTokens(vocab_size=32, seed=0)
    batch = make_batch(src, 0, ladder[0], seq_len=4)
    engine.warmup(ladder[1], batch)
    drainer = threading.Thread(target=lambda: engine.drain(raise_errors=False))
    drainer.start()
    # wait until drain CLAIMED the (still-running) warmup future
    deadline = _time.monotonic() + 10
    while engine._pending:
        assert _time.monotonic() < deadline, "drain never claimed the warmup"
        _time.sleep(0.005)
    # the racing get_step finds nothing pending -> synchronous fallback
    # build; it must NOT account the same future a second time
    plan2 = ladder[1]
    batch2 = pad_to_bucket(make_batch(src, 1, plan2, seq_len=4), plan2, plan2)
    step = engine.get_step(batch2)
    assert isinstance(step, BlockingExploder)
    release.set()                      # let the background failure surface
    drainer.join(timeout=30)
    assert not drainer.is_alive()
    assert engine.stats.warmup_failures == 1   # was 2 with the stale copy
    assert engine.stats.compiles == 1          # only the sync fallback
    engine.drain(raise_errors=False)           # idempotent: nothing pending
    assert engine.stats.warmup_failures == 1


def test_run_training_engine_stats_end_to_end():
    """The engine threads through launch/train.py: an adaptive run reports
    compiles == buckets used, and a new seq_len bucket is a new compile."""
    from repro.launch.train import TrainJob, run_training
    job = TrainJob(arch="llama3.2-1b", steps=8, seq_len=32,
                   base_global_batch=4, max_global_batch=16,
                   base_micro_batch=2, max_micro_batch=2, base_accum=2,
                   eta=0.12, step_impl="accum_norm", eval_every=0)
    h = run_training(job)
    eng = h["engine"]
    assert eng["steps"] == 8
    assert eng["compiles"] == len(eng["buckets_used"])
    assert eng["hits"] == eng["steps"] - eng["compiles"]
    assert all(np.isfinite(l) for l in h["loss"])


def test_warmup_agreed_proposal_targets_requested_rung():
    """`warmup_agreed` warms the CALLER's proposal (the predicted target
    rung, DESIGN §14) when one is given — not blindly the next rung up —
    and still defaults to next_bucket without one."""
    ladder = parse_ladder("2:1,2:2,2:4,2:8", workers=1)
    builds = []

    class FakeJitted:
        def lower(self, *a):
            return self

        def compile(self):
            return lambda *a: None

    def aot_wrap(batch_like):
        builds.append(batch_like["tokens"].shape[:2])
        return FakeJitted()

    engine = BucketedEngine(aot_wrap, ladder, params_like={}, opt_like={},
                            aot_warmup=True)
    src = MarkovTokens(vocab_size=32, seed=0)
    batch = make_batch(src, 0, ladder[0], seq_len=4)
    # predicted rung two levels up: warm THAT one, skipping ladder[1]
    queued = engine.warmup_agreed(ladder[0], batch, proposal=ladder[2])
    engine.drain()
    assert queued == ladder[2]
    assert builds == [(ladder[2].accum_steps, ladder[2].micro_batch)]
    # no proposal: the pre-predictor default (next rung up)
    queued = engine.warmup_agreed(ladder[0], batch)
    engine.drain()
    assert queued == ladder[1]
    assert builds[-1] == (ladder[1].accum_steps, ladder[1].micro_batch)
    # stepping into the predicted rung later is a transition HIT
    plan2 = ladder[2]
    b0 = pad_to_bucket(make_batch(src, 0, ladder[0], seq_len=4),
                       ladder[0], ladder[0])
    b2 = pad_to_bucket(make_batch(src, 1, plan2, seq_len=4), plan2, plan2)
    engine.get_step(b0)
    engine.get_step(b2)
    assert engine.stats.transitions == 1
    assert engine.stats.transition_hits == 1


def test_predictive_run_rung_transitions_are_cache_hits():
    """Acceptance: predictive mode at smoke scale warms the rung the
    controller actually transitions to — every measured rung transition is
    a cache hit (the foreground never traces it), with per-rung compiles
    unchanged.  Base 32 of a 64-ladder so the two-scale GNS estimate is
    valid (M·J large) and the predictor populates mid-run."""
    from repro.launch.train import TrainJob, run_training
    job = TrainJob(arch="llama3.2-1b", steps=8, seq_len=32,
                   base_global_batch=32, max_global_batch=64,
                   base_micro_batch=2, max_micro_batch=2, base_accum=2,
                   eta=0.12, step_impl="accum_norm", eval_every=0,
                   predict=True, aot_warmup=True)
    h = run_training(job)
    eng = h["engine"]
    assert eng["transitions"] >= 1
    assert eng["transition_hits"] == eng["transitions"]
    # one compile per rung visited, none of them foreground at a transition
    assert eng["compiles"] == len(eng["buckets_used"])
    # the predictor populated and targeted the rung the run sits on
    assert any(r == 64 for r in h["pred_rung"])
    assert all(np.isfinite(l) for l in h["loss"])


def test_padded_batch_identical_grads_fsdp_multiworker(subproc):
    """Padding that lands unevenly across the J workers still yields the
    unpadded loss/params: the per-worker means are valid-token weighted
    before the cross-worker reduction (DESIGN §8)."""
    out = subproc("""
import jax, jax.numpy as jnp
import numpy as np
from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.distributed.train_step import make_fsdp_norm_step
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.data.pipeline import MarkovTokens, make_batch, pad_to_bucket
from repro.core.schedule import BatchPlan

cfg = get_smoke_config("llama3.2-1b")
model = build_model(cfg)
mesh = make_host_mesh(data=2, model=1)
src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
plan = BatchPlan(global_batch=6, micro_batch=3, accum_steps=1, workers=2)
bucket = BatchPlan(global_batch=16, micro_batch=4, accum_steps=2, workers=2)
batch = make_batch(src, 0, plan, 16)
padded = pad_to_bucket(batch, plan, bucket)
# row-major fill of 6 reals into (2, 8): row0 = 6 real + 2 pad, so worker 0
# holds 4 real and worker 1 holds 2 real + 2 pad -> uneven by construction
outs = {}
for tag, b in (("plain", batch), ("padded", padded)):
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    wrap, _, _ = make_fsdp_norm_step(model, AdamWConfig(), mesh,
                                     params_like=params)
    jb = jax.tree.map(jnp.asarray, b)
    fn = wrap(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), jb))
    with set_mesh(mesh):
        p2, _, m = fn(params, opt, jb, jnp.float32(1e-3))
    outs[tag] = (p2, float(m["loss"]))
assert abs(outs["plain"][1] - outs["padded"][1]) < 1e-5, outs
for a, b in zip(jax.tree.leaves(outs["plain"][0]),
                jax.tree.leaves(outs["padded"][0])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-5, atol=1e-5)
print("FSDP_PAD_OK")
""", devices=2)
    assert "FSDP_PAD_OK" in out


def test_get_step_concurrent_callers_compile_once():
    """Regression for the unlocked-cache race: `get_step` used to read and
    write `self._cache` outside `self._lock`, so a foreground build racing
    another caller (e.g. a finishing AOT warmup) could trace the same
    signature twice and double-count `stats.compiles`.  N threads asking
    for the same batch must produce exactly ONE compile; everyone else is
    a hit."""
    import threading
    import time as _time

    ladder = parse_ladder("2:1,2:2", workers=1)
    builds = []
    entered = threading.Barrier(4 + 1, timeout=10)

    def slow_wrap(batch_like):
        builds.append(tuple(v.shape for v in batch_like.values()))
        _time.sleep(0.05)          # widen the race window
        return lambda *a: ("step", len(builds))

    engine = BucketedEngine(slow_wrap, ladder)
    src = MarkovTokens(vocab_size=32, seed=0)
    batch = make_batch(src, 0, ladder[0], seq_len=4)

    results, errors = [], []

    def worker():
        try:
            entered.wait()
            results.append(engine.get_step(batch))
        except Exception as e:     # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    entered.wait()                 # release all workers at once
    for t in threads:
        t.join(timeout=10)
    assert not errors
    assert len(builds) == 1, f"double-compiled: {len(builds)} traces"
    assert engine.stats.compiles == 1
    assert engine.stats.hits == 3
    assert len({id(fn) for fn in results}) == 1   # everyone got THE step


def test_flat_resident_layout_reused_across_rungs_zero_packs():
    """DESIGN §10 engine invariant: a flat-resident step builder exposes ONE
    `FlatLayout` (`wrap.flat_layout`), every ladder rung the engine compiles
    reuses it (the engine asserts identity at build time), and the step
    TRACED at each rung contains zero pack eqns — buffers from one rung
    feed the step compiled for the next with no residency conversion.

    Pack counting is jaxpr-level (`engine.trace_step` +
    `repro.analysis.count_layout_ops`), not the deprecated Python-call
    proxy: the marker eqns are visible regardless of jit caching, so the
    zero-pack claim is about the compiled graph itself."""
    from repro.compat import set_mesh
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.launch.mesh import make_host_mesh
    from repro.distributed.train_step import make_accum_norm_step
    from repro.analysis.jaxpr_check import LAYOUT_MARKER, iter_eqns
    from repro.optim.adamw import AdamWConfig, init_adamw_flat

    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    params = model.init(jax.random.PRNGKey(0))
    wrap, _, _ = make_accum_norm_step(model, AdamWConfig(), mesh,
                                      stats_impl="flat", params_impl="flat",
                                      params_like=params)
    layout = wrap.flat_layout
    assert layout is not None
    opt = init_adamw_flat(params, layout=layout)
    pb = tuple(layout.flatten(params))
    abstract = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)

    ladder = parse_ladder("2:1,2:2", workers=1)
    engine = BucketedEngine(wrap, ladder, mesh=mesh,
                            params_like=abstract(pb), opt_like=abstract(opt))
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    with set_mesh(mesh):
        for rung in ladder:
            batch = jax.tree.map(jnp.asarray,
                                 make_batch(src, 0, rung, seq_len=16))
            jaxpr = engine.trace_step(batch)
            packs = [e for e in iter_eqns(jaxpr.jaxpr)
                     if e.primitive.name == LAYOUT_MARKER
                     and e.params["kind"] == "pack"]
            assert not packs, (
                f"rung {rung.global_batch}: {len(packs)} pack eqns in a "
                "flat-resident steady-state step")
            fn = engine.get_step(batch)
            assert wrap.flat_layout is layout      # one layout, every rung
            pb, opt, m = fn(pb, opt, batch, jnp.float32(1e-3))
            assert np.isfinite(float(m["loss"]))
    assert engine.stats.compiles == len(ladder)


def test_stagewise_stage_above_max_global_trains():
    """Regression: a stagewise stage configured above max_global_batch must
    ride the auto ladder's extended top rung, not crash in pad_to_bucket."""
    from repro.launch.train import TrainJob, run_training
    job = TrainJob(arch="llama3.2-1b", schedule="stagewise",
                   stages=((0.25, 8), (0.75, 32)), steps=8, total_samples=64,
                   seq_len=16, base_global_batch=4, max_global_batch=16,
                   base_micro_batch=2, max_micro_batch=2, base_accum=2,
                   step_impl="accum_norm", eval_every=0)
    h = run_training(job)
    assert max(h["global_batch"]) == 32       # the above-cap stage executed
    assert all(np.isfinite(l) for l in h["loss"])


def test_explicit_ladder_rungs_above_cap_are_ineligible():
    """Regression: quantization never hands the controller a rung above
    max_global_batch, even from an explicit over-provisioned ladder."""
    ladder = parse_ladder("2:1,2:24,2:48", workers=1)   # rungs 2, 48, 96
    rung = quantize_to_ladder(10_000, ladder, max_global=64)
    assert rung.global_batch == 48             # largest eligible, not 96

    cfg = ControllerConfig(eta=0.5, workers=1, base_micro_batch=2,
                           max_micro_batch=2, base_accum=1,
                           base_global_batch=2, max_global_batch=64,
                           ladder=ladder)
    s = init_controller(cfg)
    s = controller_update(cfg, s, var_l1=1e12, grad_sqnorm=1.0)
    assert s.plan.global_batch == 48 and s.at_max   # latched at the ceiling
    s2 = controller_update(cfg, s, var_l1=1e15, grad_sqnorm=1.0)
    assert s2.plan.global_batch == 48
