"""Local-update training (companion scheme, arXiv:2406.13936): H local steps
between syncs; inter-worker divergence drives the adaptive batch."""
import jax
import pytest


def test_local_sgd_round_and_divergence_signal(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.distributed.local_step import make_local_sgd_step
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.data.pipeline import MarkovTokens, make_batch
from repro.core.schedule import BatchPlan

cfg = get_smoke_config("llama3.2-1b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = init_adamw(params)
mesh = make_host_mesh(data=4, model=1)
src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
H = 3
plan = BatchPlan(global_batch=8, micro_batch=2, accum_steps=1, workers=4)
# stack H local-step batches
import numpy as np
bs = [make_batch(src, s, plan, 16) for s in range(H)]
batch = {k: jnp.asarray(np.stack([b[k][0] for b in bs])) for k in bs[0]}
wrap, _, _ = make_local_sgd_step(model, AdamWConfig(), mesh, params_like=params)
rnd = wrap(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
with set_mesh(mesh):
    p2, o2, m = rnd(params, opt, batch, jnp.float32(5e-3))
assert all(bool(jnp.isfinite(v)) for v in jax.tree.leaves(m)), m
# workers saw different data for H steps -> replicas diverged -> signal > 0
assert float(m["var_l1"]) > 0, m
assert float(m["grad_sqnorm"]) > 0
# after sync all replicas identical: feeding IDENTICAL data to all workers
# must produce zero divergence
same = {k: jnp.asarray(np.stack([np.tile(b[k][0][:2], (4,1)) for b in bs])) for k in bs[0]}
rnd2 = wrap(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), same))
with set_mesh(mesh):
    p3, o3, m2 = rnd2(p2, o2, same, jnp.float32(5e-3))
assert float(m2["var_l1"]) < 1e-8 * max(float(m2["grad_sqnorm"]), 1e-9), m2
print("LOCAL_OK", float(m["var_l1"]), float(m2["var_l1"]))
""", devices=4)
    assert "LOCAL_OK" in out


def test_local_sgd_rejects_tree_stats_over_flat_params():
    """Local-SGD has no tree-oracle tail over flat params (the flat round
    always runs the buffer AdamW) — the combo must be rejected loudly."""
    import pytest
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.launch.mesh import make_host_mesh
    from repro.distributed.local_step import make_local_sgd_step
    from repro.optim.adamw import AdamWConfig

    model = build_model(get_smoke_config("llama3.2-1b"))
    mesh = make_host_mesh(data=1, model=1)
    with pytest.raises(ValueError):
        make_local_sgd_step(model, AdamWConfig(), mesh,
                            stats_impl="tree", params_impl="flat")


def test_local_sgd_flat_resident_matches_tree():
    """DESIGN §10 on the local-SGD round: a flat-resident replica (gradients
    born flat every local step, buffer AdamW, buffer divergence statistic)
    reproduces the tree round's metrics and synced params to 1e-5, with
    ZERO packs in the traced round."""
    import numpy as np
    import jax.numpy as jnp
    from repro.compat import set_mesh
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.launch.mesh import make_host_mesh
    from repro.distributed.local_step import make_local_sgd_step
    from repro.analysis import count_layout_ops
    from repro.optim.adamw import AdamWConfig, init_adamw, init_adamw_flat

    from repro.data.pipeline import MarkovTokens, make_batch
    from repro.core.schedule import BatchPlan

    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    plan = BatchPlan(global_batch=2, micro_batch=2, accum_steps=1, workers=1)
    bs = [make_batch(src, s, plan, 16) for s in range(3)]     # H = 3
    batch = {k: jnp.asarray(np.stack([b[k][0] for b in bs])) for k in bs[0]}
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)

    res = {}
    for params_impl in ("tree", "flat"):
        params = model.init(jax.random.PRNGKey(0))
        wrap, _, _ = make_local_sgd_step(model, AdamWConfig(), mesh,
                                         stats_impl=params_impl,
                                         params_impl=params_impl,
                                         params_like=params)
        layout = wrap.flat_layout
        opt = (init_adamw_flat(params, layout=layout)
               if params_impl == "flat" else init_adamw(params))
        if params_impl == "flat":
            params = tuple(layout.flatten(params))
        with set_mesh(mesh):
            if params_impl == "flat":
                # jaxpr-eqn count: zero pack eqns in the traced flat round
                ops_seen = count_layout_ops(
                    wrap(sds), params, opt, batch, jnp.float32(5e-3))
                assert not ops_seen["pack"], (
                    f"{len(ops_seen['pack'])} pack eqns in flat-resident "
                    f"round: {ops_seen}")
            p2, _, m = wrap(sds)(params, opt, batch, jnp.float32(5e-3))
        if params_impl == "flat":
            p2 = layout.unflatten(list(p2))
        res[params_impl] = (p2, m)
    for k in ("loss", "var_l1", "grad_sqnorm"):
        np.testing.assert_allclose(float(res["tree"][1][k]),
                                   float(res["flat"][1][k]),
                                   rtol=1e-5, atol=1e-8, err_msg=k)
    for a, b in zip(jax.tree.leaves(res["tree"][0]),
                    jax.tree.leaves(res["flat"][0])):
        # atol 5e-6: the embedding-table scatter adjoint reorders its adds
        # when differentiated through the buffer slice (H chained steps
        # compound the reassociation to ~1e-6 absolute)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=5e-6)
