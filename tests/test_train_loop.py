"""End-to-end integration: the adaptive loop trains, grows batches, and beats
noise; checkpoints round-trip through the driver."""
import math

import numpy as np
import pytest

from repro.launch.train import TrainJob, run_training, summarize


def test_adaptive_run_grows_batch_and_learns(tmp_path):
    job = TrainJob(arch="llama3.2-1b", steps=40, seq_len=64,
                   base_global_batch=4, max_global_batch=64,
                   base_micro_batch=2, max_micro_batch=4, base_accum=2,
                   eta=0.12, step_impl="accum_norm", eval_every=20,
                   log_path=str(tmp_path / "log.csv"))
    hist = run_training(job)
    s = summarize(hist)
    assert hist["global_batch"][-1] > hist["global_batch"][0], "batch must grow"
    assert hist["loss"][-1] < hist["loss"][0], "loss must decrease"
    assert all(math.isfinite(l) for l in hist["loss"])
    # log file written with all columns
    lines = (tmp_path / "log.csv").read_text().strip().splitlines()
    assert len(lines) == 41 and lines[0].startswith("step,")


def test_constant_schedule_stays_constant():
    job = TrainJob(arch="llama3.2-1b", schedule="constant", steps=6,
                   seq_len=32, base_global_batch=8, max_global_batch=8,
                   base_micro_batch=2, max_micro_batch=2, base_accum=4,
                   eval_every=0)
    hist = run_training(job)
    assert set(hist["global_batch"]) == {8}


def test_stagewise_schedule_ramps():
    job = TrainJob(arch="llama3.2-1b", schedule="stagewise", steps=30,
                   seq_len=32, total_samples=600,
                   stages=((0.2, 8), (0.2, 16), (0.6, 32)),
                   base_micro_batch=2, max_micro_batch=4, base_accum=2,
                   eval_every=0)
    hist = run_training(job)
    batches = hist["global_batch"]
    assert batches[0] == 8
    assert max(batches) == 32
    assert sorted(set(batches)) == [8, 16, 32]


def test_checkpoint_written(tmp_path):
    from repro.checkpoint.store import latest_step
    job = TrainJob(arch="llama3.2-1b", steps=3, seq_len=32,
                   base_global_batch=4, max_global_batch=4,
                   base_micro_batch=2, max_micro_batch=2, base_accum=1,
                   eval_every=0, checkpoint_dir=str(tmp_path / "ckpt"))
    run_training(job)
    assert latest_step(str(tmp_path / "ckpt")) == 3


def test_sequence_length_warmup():
    """Paper §2: sequence-length warmup composes with batch schedules."""
    job = TrainJob(arch="llama3.2-1b", schedule="constant", steps=12,
                   total_samples=12 * 8, seq_len=64,
                   seq_stages=((0.3, 16), (0.3, 32), (0.4, 64)),
                   base_global_batch=8, max_global_batch=8,
                   base_micro_batch=2, max_micro_batch=2, base_accum=2,
                   eval_every=0)
    hist = run_training(job)
    assert hist["loss"][0] > 0  # ran
    assert len(hist["step"]) == 12
