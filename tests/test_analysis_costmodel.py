"""Layer-3 (cost model + SPMD divergence, DESIGN §15) tests.

The acceptance bar is the planted-regression suite: a step graph with one
extra all-gather, one with a dropped donation, one with rank-dependent
collective order, and one with a cond-branch collective mismatch — the
analyzer must flag all of them, and must pass clean on their unplanted
twins.  Plus the budget lifecycle: round-trip through `write_budget`,
symmetric drift detection, staleness in both directions, and the
`--update-budget` flow.

Planted fixtures live in tests/fixtures/costmodel/planted.py (trace-only;
nothing here compiles or executes a step).
"""

import importlib.util
import json
import pathlib

import pytest

import jax
import jax.numpy as jnp

from repro.analysis.costmodel import (
    DEFAULT_TOLERANCES, budget_diff, collective_kind, collective_profile,
    flops_estimate, load_budget, peak_memory, run_cost_checks, variant_cost,
    write_budget)
from repro.analysis.divergence import (
    branch_collective_mismatches, check_fn_divergence, collective_signature)
from repro.analysis.jaxpr_check import main_arg_attrs, trace

FIXTURE = (pathlib.Path(__file__).parent / "fixtures" / "costmodel" /
           "planted.py")


def _planted():
    spec = importlib.util.spec_from_file_location("costmodel_planted", FIXTURE)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def planted():
    return _planted()


@pytest.fixture(scope="module")
def mesh(planted):
    return planted.fixture_mesh()


def _x(mesh):
    return jnp.zeros((4 * mesh.devices.size,), jnp.float32)


# ------------------------------------------- planted regression: comms ----

def test_planted_extra_allgather_flagged(planted, mesh, tmp_path):
    """The planted extra all_gather shows up in the profile (new kind, new
    bytes) and trips the budget diff with an exact op-count finding."""
    from repro.compat import set_mesh
    x = _x(mesh)
    with set_mesh(mesh):
        clean = trace(planted.clean_step(mesh), x)
        dirty = trace(planted.extra_gather_step(mesh), x)
    p_clean = collective_profile(clean)
    p_dirty = collective_profile(dirty)
    assert "psum" in p_clean["per_kind"]
    assert "all_gather" not in p_clean["per_kind"]
    ag = p_dirty["per_kind"]["all_gather"]
    assert ag["count"] == 1 and ag["bytes"] > 0

    def metrics(traced):
        return {"collectives": collective_profile(traced)["per_kind"],
                "flatbuf": {"count": 0, "bytes": 0},
                "flops": flops_estimate(traced),
                "peak_bytes": peak_memory(traced), "donated_aliased": 0}

    budget = write_budget(tmp_path / "b.json", {"planted": metrics(clean)})
    findings = budget_diff({"planted": metrics(dirty)}, budget)
    comm = [f for f in findings if f.rule == "cost-collectives"]
    assert comm and any("all_gather" in f.message for f in comm)


# ----------------------------------------- planted regression: donation ----

def test_planted_dropped_donation_raises_watermark(planted):
    """peak_memory prices the dropped donation at exactly the
    double-allocated params buffer, and the budget diff calls out the
    aliased-input decrease."""
    n = 1 << 16
    good_fn, good_args = planted.donating_update(n)
    bad_fn, bad_args = planted.dropped_donation_update(n)
    peaks, aliased = {}, {}
    for tag, (fn, args) in (("good", (good_fn, good_args)),
                            ("bad", (bad_fn, bad_args))):
        attrs = main_arg_attrs(fn.lower(*args).as_text())
        aliased[tag] = sum(1 for a in attrs if a.aliased)
        peaks[tag] = peak_memory(trace(fn, *args), attrs)
    assert aliased["good"] >= 1 and aliased["bad"] == 0
    buf = n * 4
    assert peaks["bad"] >= peaks["good"] + buf // 2, (peaks, buf)

    base = {"collectives": {}, "flatbuf": {"count": 0, "bytes": 0},
            "flops": 100}
    findings = budget_diff(
        {"v": {**base, "peak_bytes": peaks["bad"],
               "donated_aliased": aliased["bad"]}},
        {"schema": 1, "tolerances": DEFAULT_TOLERANCES,
         "topology": {"device_count": jax.device_count()},
         "variants": {"v": {**base, "peak_bytes": peaks["good"],
                            "donated_aliased": aliased["good"]}}})
    mem = [f for f in findings if f.rule == "cost-peak-memory"]
    assert any("donation was dropped" in f.message for f in mem)
    assert any("watermark" in f.message for f in mem)


# --------------------------------------- planted regression: divergence ----

def test_planted_rank_dependent_order_flagged(planted, mesh):
    """Two traces of the order-flipping builder produce different ordered
    collective signatures -> divergence-order; the clean step is stable."""
    x = _x(mesh)
    findings = check_fn_divergence(planted.make_flipping_step(mesh), (x,),
                                   "planted/flip", mesh)
    assert [f.rule for f in findings] == ["divergence-order"]
    assert "deadlock" in findings[0].message
    assert check_fn_divergence(planted.clean_step(mesh), (x,),
                               "planted/clean", mesh) == []


def test_planted_cond_branch_mismatch_flagged(planted, mesh):
    """A psum under only one cond branch -> divergence-cond, and the raw
    mismatch API names the cond site."""
    from repro.compat import set_mesh
    x = _x(mesh)
    with set_mesh(mesh):
        traced = trace(planted.cond_collective_step(mesh), x)
    mismatches = branch_collective_mismatches(traced)
    assert len(mismatches) == 1
    label, sigs = mismatches[0]
    assert "cond" in label and {len(s) for s in sigs} == {0, 1}
    findings = check_fn_divergence(planted.cond_collective_step(mesh), (x,),
                                   "planted/cond", mesh)
    assert "divergence-cond" in [f.rule for f in findings]


def test_collective_signature_orders_and_scopes(planted, mesh):
    """The signature is ordered and scope-tagged: clean step = one psum,
    extra-gather step = psum then all_gather, in emission order."""
    from repro.compat import set_mesh
    x = _x(mesh)
    with set_mesh(mesh):
        sig = collective_signature(trace(planted.extra_gather_step(mesh), x))
    kinds = [collective_kind(name) for _, name, _, _ in sig]
    assert kinds == ["psum", "all_gather"]
    assert all(ax == ("d",) for _, _, ax, _ in sig)


# ----------------------------------------------------- budget lifecycle ----

def _fake_variant(planted, mesh):
    """A StepVariant-shaped object over the cheap planted clean step, so
    the budget lifecycle tests never trace the full smoke model."""
    from repro.analysis.invariants import LayoutCounts, StepVariant
    return StepVariant(name="planted/clean", fn=planted.clean_step(mesh),
                       args=(_x(mesh),), expected=LayoutCounts(0, 0, 0),
                       spec_prefix=[], flat_groups=[], layout=None)


def test_budget_roundtrip_update_and_drift(planted, mesh, tmp_path):
    """measure -> --update-budget -> clean diff; then each perturbation
    class (flops drift, collective count, peak) fires its own rule; an
    IMPROVEMENT fails symmetrically."""
    v = _fake_variant(planted, mesh)
    path = tmp_path / "analysis_budget.json"

    # missing budget is itself a finding, not a crash
    findings, checked = run_cost_checks(path, variants=[v])
    assert [f.rule for f in findings] == ["budget-stale"]
    assert "planted/clean" in checked["metrics"]

    # the update flow writes the file and reports clean
    findings, checked = run_cost_checks(path, variants=[v], update=True)
    assert findings == [] and checked["budget_updated"]
    budget = load_budget(path)
    assert budget["schema"] == 1
    assert budget["topology"]["device_count"] == jax.device_count()
    assert budget["variants"]["planted/clean"]["flops"] > 0

    # round-trip: a fresh measurement against the fresh budget is clean
    findings, _ = run_cost_checks(path, variants=[v])
    assert findings == []

    # perturbations: each metric fires its own rule, both directions
    for mutate, rule in (
            (lambda e: e.update(flops=int(e["flops"] * 2)), "cost-flops"),
            (lambda e: e.update(flops=int(e["flops"] * 0.5)), "cost-flops"),
            (lambda e: e["collectives"]["psum"].update(
                count=e["collectives"]["psum"]["count"] + 1),
             "cost-collectives"),
            (lambda e: e.update(peak_bytes=int(e["peak_bytes"] * 2)),
             "cost-peak-memory")):
        b = json.loads(path.read_text())
        mutate(b["variants"]["planted/clean"])
        (tmp_path / "mut.json").write_text(json.dumps(b))
        findings, _ = run_cost_checks(tmp_path / "mut.json", variants=[v])
        assert rule in [f.rule for f in findings], (rule, findings)


def test_budget_staleness_both_directions():
    """Variant-set drift between budget and matrix is a finding either way,
    and a topology mismatch short-circuits everything else."""
    m = {"collectives": {}, "flatbuf": {"count": 0, "bytes": 0}, "flops": 1,
         "peak_bytes": 1, "donated_aliased": 0}
    budget = {"schema": 1, "tolerances": DEFAULT_TOLERANCES,
              "topology": {"device_count": jax.device_count()},
              "variants": {"only/in/budget": dict(m)}}
    findings = budget_diff({"only/in/matrix": dict(m)}, budget)
    locs = {f.location for f in findings}
    assert {f.rule for f in findings} == {"budget-stale"}
    assert locs == {"only/in/budget", "only/in/matrix"}

    stale_topo = {**budget, "topology": {"device_count":
                                         jax.device_count() + 7}}
    findings = budget_diff({"only/in/matrix": dict(m)}, stale_topo)
    assert len(findings) == 1 and "device_count" in findings[0].message


def test_committed_budget_matches_matrix_shape():
    """The committed analysis_budget.json names exactly the traced matrix's
    variants (staleness guard at the repo level, no tracing needed)."""
    from repro.analysis.invariants import EXPECTED_LAYOUT_COUNTS
    repo = pathlib.Path(__file__).parent.parent
    budget = load_budget(repo / "analysis_budget.json")
    assert budget is not None, "analysis_budget.json must be committed"
    names = set(budget["variants"])
    assert "serve_decode/rung2" in names
    # every train combo in the expected matrix has a budget entry
    for (impl, stats, params) in EXPECTED_LAYOUT_COUNTS:
        if impl == "serve_decode":
            continue
        assert f"{impl}/{stats}/{params}" in names, (impl, stats, params)
    for v in budget["variants"].values():
        assert {"collectives", "flatbuf", "flops", "peak_bytes",
                "donated_aliased"} <= set(v)


# -------------------------------------------------- engine lowered-HLO ----

def test_engine_lower_step_exposes_hlo_without_compiling():
    """`BucketedEngine.lower_step` hands layer 3 the lowered module (text
    with donation aliasing visible) while stats prove nothing compiled and
    the cache stayed empty."""
    from repro.compat import set_mesh
    from repro.configs import get_smoke_config
    from repro.core.schedule import parse_ladder
    from repro.data.pipeline import MarkovTokens, make_batch
    from repro.distributed.engine import BucketedEngine
    from repro.distributed.train_step import make_accum_norm_step
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig, init_adamw

    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    params = model.init(jax.random.PRNGKey(0))
    wrap, _, _ = make_accum_norm_step(model, AdamWConfig(), mesh,
                                      params_like=params)
    ladder = parse_ladder("2:1,2:2", workers=1)
    engine = BucketedEngine(wrap, ladder, mesh=mesh, params_like=params,
                            opt_like=init_adamw(params))
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    with set_mesh(mesh):
        batch = jax.tree.map(jnp.asarray, make_batch(src, 0, ladder[0], 16))
    lowered = engine.lower_step(batch)
    text = lowered.as_text()
    assert "func.func" in text and "tf.aliasing_output" in text
    attrs = main_arg_attrs(text)
    assert sum(1 for a in attrs if a.aliased) > 0
    assert engine.stats.compiles == 0 and engine.stats.hits == 0
