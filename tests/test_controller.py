"""Algorithm 1 controller: unit + hypothesis property tests."""
import json
import math

import pytest
from proptest import given, settings, st

from repro.core.controller import (
    ControllerConfig, controller_state_as_dict, controller_state_from_dict,
    init_controller, controller_update)
from repro.core.schedule import round_plan


@given(desired=st.integers(1, 10_000_000), workers=st.sampled_from([1, 2, 4, 16, 32]),
       micro=st.sampled_from([1, 2, 4, 8]), max_micro=st.sampled_from([8, 16]),
       accum=st.sampled_from([1, 2, 16]))
@settings(max_examples=200, deadline=None)
def test_round_plan_invariants(desired, workers, micro, max_micro, accum):
    max_global = 8192
    plan = round_plan(desired, workers, micro, max_micro, accum, max_global)
    # Algorithm 1 rounding chain invariants
    assert plan.global_batch == plan.workers * plan.accum_steps * plan.micro_batch
    assert plan.micro_batch <= max_micro
    assert plan.micro_batch >= 1 and plan.accum_steps >= 1
    assert plan.global_batch <= max(max_global, workers * micro)
    if desired <= max_global:
        # rounded result must cover the request
        assert plan.global_batch >= min(desired, max_global) or \
            plan.global_batch + workers * plan.micro_batch > min(desired, max_global)


@given(var=st.floats(0, 1e6, allow_nan=False), gsq=st.floats(1e-6, 1e6),
       eta=st.floats(0.05, 0.9))
@settings(max_examples=100, deadline=None)
def test_controller_monotone_and_clamped(var, gsq, eta):
    cfg = ControllerConfig(eta=eta, workers=4, base_micro_batch=2,
                           max_micro_batch=8, base_accum=2,
                           base_global_batch=16, max_global_batch=1024)
    st_ = init_controller(cfg)
    prev = st_.plan.global_batch
    for _ in range(5):
        st_ = controller_update(cfg, st_, var, gsq)
        assert st_.plan.global_batch >= prev          # monotonic growth
        assert st_.plan.global_batch <= 1024          # clamped
        prev = st_.plan.global_batch


def test_controller_grows_exactly_when_T_exceeds_b():
    cfg = ControllerConfig(eta=0.5, workers=2, base_micro_batch=1,
                           max_micro_batch=1, base_accum=1,
                           base_global_batch=2, max_global_batch=4096)
    s = init_controller(cfg)
    assert s.plan.global_batch == 2
    # T = var/(eta^2 gsq) = 100/(0.25*1) = 400 > 2 -> grow to >= 400
    s = controller_update(cfg, s, var_l1=100.0, grad_sqnorm=1.0)
    assert s.plan.global_batch >= 400
    assert s.plan.global_batch % 2 == 0
    # T below current batch -> keep
    b = s.plan.global_batch
    s = controller_update(cfg, s, var_l1=1e-9, grad_sqnorm=1.0)
    assert s.plan.global_batch == b


def test_at_max_latch_stops_testing():
    cfg = ControllerConfig(eta=0.1, workers=1, base_micro_batch=1,
                           max_micro_batch=1, base_accum=1,
                           base_global_batch=1, max_global_batch=8)
    s = init_controller(cfg)
    s = controller_update(cfg, s, var_l1=1e9, grad_sqnorm=1.0)
    assert s.plan.global_batch == 8 and s.at_max
    s2 = controller_update(cfg, s, var_l1=1e9, grad_sqnorm=1.0)
    assert s2.plan.global_batch == 8


def test_test_interval_skips():
    cfg = ControllerConfig(eta=0.1, workers=1, base_micro_batch=1,
                           max_micro_batch=1, base_accum=1,
                           base_global_batch=1, max_global_batch=1024,
                           test_interval=3)
    s = init_controller(cfg)
    s = controller_update(cfg, s, 1e9, 1.0)   # step 1: skipped (1 % 3 != 0)
    assert s.plan.global_batch == 1
    s = controller_update(cfg, s, 1e9, 1.0)   # step 2: skipped
    assert s.plan.global_batch == 1
    s = controller_update(cfg, s, 1e9, 1.0)   # step 3: tested
    assert s.plan.global_batch > 1


# ----------------------------------------- ladder-quantized controller ----

def _ladder_cfg(workers, **kw):
    from repro.core.schedule import bucket_ladder
    base = dict(eta=0.15, workers=workers, base_micro_batch=2,
                max_micro_batch=8, base_accum=2,
                base_global_batch=2 * workers, max_global_batch=128 * workers)
    base.update(kw)
    cfg = ControllerConfig(**base)
    ladder = bucket_ladder(cfg.workers, cfg.base_micro_batch,
                           cfg.max_micro_batch, cfg.base_accum,
                           cfg.base_global_batch, cfg.max_global_batch)
    return ControllerConfig(**{**base, "ladder": ladder})


@pytest.mark.parametrize("workers", [1, 2, 8])
def test_ladder_controller_monotonic_growth(workers):
    """monotonic=True on the ladder: global_batch never shrinks, every plan
    is a ladder rung, and the cap holds — under an adversarial T_k stream."""
    cfg = _ladder_cfg(workers)
    s = init_controller(cfg)
    rungs = {p.global_batch for p in cfg.ladder}
    prev = s.plan.global_batch
    stream = [1e9, 1e-9, 50.0, 1e-9, 1e9, 3.0, 1e9, 1e-9]
    for var in stream:
        s = controller_update(cfg, s, var_l1=var, grad_sqnorm=1.0)
        assert s.plan.global_batch >= prev
        assert s.plan.global_batch <= cfg.max_global_batch
        assert s.plan.global_batch in rungs
        prev = s.plan.global_batch


@pytest.mark.parametrize("workers", [1, 2, 8])
def test_ladder_controller_at_max_latches(workers):
    cfg = _ladder_cfg(workers)
    s = init_controller(cfg)
    s = controller_update(cfg, s, var_l1=1e12, grad_sqnorm=1.0)
    top = cfg.ladder[-1].global_batch
    assert s.plan.global_batch == top and s.at_max
    # latched: even a huge statistic no longer changes the plan
    s2 = controller_update(cfg, s, var_l1=1e15, grad_sqnorm=1.0)
    assert s2.plan.global_batch == top and s2.at_max
    assert s2.last_T == s.last_T  # the test did not even run


@pytest.mark.parametrize("workers", [1, 2, 8])
def test_ladder_controller_test_interval_skips(workers):
    cfg = _ladder_cfg(workers, test_interval=4)
    s = init_controller(cfg)
    base = s.plan.global_batch
    for step in range(1, 4):       # steps 1-3: test skipped
        s = controller_update(cfg, s, var_l1=1e9, grad_sqnorm=1.0)
        assert s.plan.global_batch == base, step
    s = controller_update(cfg, s, var_l1=1e9, grad_sqnorm=1.0)  # step 4
    assert s.plan.global_batch > base
    assert s.plan.global_batch in {p.global_batch for p in cfg.ladder}


def test_ema_first_tested_step_seeds_not_blends():
    """Regression: the EMA cold start must SEED from the first real
    observation.  The old `state.step > 0` proxy for "EMA holds data"
    failed with test_interval > 1 — the first tested step arrives at
    step >= 1 with ema_stat still 0.0, and blending against the
    placeholder halved T_k (ema=0.5), undershooting the first increase."""
    cfg = ControllerConfig(eta=0.5, workers=2, base_micro_batch=1,
                           max_micro_batch=1, base_accum=1,
                           base_global_batch=2, max_global_batch=4096,
                           test_interval=3, ema=0.5)
    s = init_controller(cfg)
    assert not s.ema_init
    s = controller_update(cfg, s, 100.0, 1.0)   # step 1: skipped
    s = controller_update(cfg, s, 100.0, 1.0)   # step 2: skipped
    assert not s.ema_init and s.ema_stat == 0.0
    # step 3: first tested step.  T_raw = 100/(0.25*1) = 400.  Seeded EMA
    # must be exactly 400 (the bug blended: 0.5*0 + 0.5*400 = 200) and the
    # plan must cover the full statistic, not half of it.
    s = controller_update(cfg, s, 100.0, 1.0)
    assert s.ema_init
    assert s.ema_stat == pytest.approx(400.0)
    assert s.plan.global_batch >= 400
    # step 6: second tested step DOES blend: 0.5*400 + 0.5*100 = 250
    s = controller_update(cfg, s, 25.0, 1.0)
    s = controller_update(cfg, s, 25.0, 1.0)
    s = controller_update(cfg, s, 25.0, 1.0)
    assert s.ema_stat == pytest.approx(250.0)


# -------------------------------------------- predictive GNS companion ----

def _predict_cfg(**kw):
    base = dict(eta=0.12, workers=1, base_micro_batch=2, max_micro_batch=2,
                base_global_batch=4, max_global_batch=64, base_accum=2,
                predict=True, gns_groups="accum")
    base.update(kw)
    return _ladder_cfg(base.pop("workers"), **base)


def _drive(cfg, state, stream):
    for var, gsq in stream:
        state = controller_update(cfg, state, var, gsq)
    return state


def test_predict_never_alters_batch_trajectory():
    """The predictor is a pure observer: with predict on and off, identical
    stat streams yield identical plan/T/EMA trajectories — the property that
    lets pre-predictor checkpoints resume bit-identically."""
    on, off = _predict_cfg(), _predict_cfg(predict=False)
    s_on, s_off = init_controller(on), init_controller(off)
    stream = [(0.01 * k, 1.0) for k in range(1, 20)]
    for var, gsq in stream:
        s_on = controller_update(on, s_on, var, gsq)
        s_off = controller_update(off, s_off, var, gsq)
        assert s_on.plan == s_off.plan
        assert s_on.last_T == s_off.last_T
        assert s_on.ema_stat == s_off.ema_stat
    assert s_on.gns_init          # ...while the predictor actually tracked
    assert not s_off.gns_init


def test_predictor_state_roundtrips_bit_exact_through_json():
    """The new predictor fields must survive the checkpoint hop exactly —
    through JSON, like checkpoint metadata does (DESIGN §12)."""
    cfg = _predict_cfg()
    s = _drive(cfg, init_controller(cfg),
               [(0.02 * k, 1.0) for k in range(1, 12)])
    assert s.gns_init and s.gns_slope_init   # non-trivial predictor state
    d = json.loads(json.dumps(controller_state_as_dict(s)))
    assert controller_state_from_dict(d) == s


def test_old_checkpoint_without_predictor_keys_loads_safe_defaults():
    """A checkpoint written before the predictor existed (no gns_*/pred_*
    keys) loads with zeroed predictor state: prediction never steers the
    batch trajectory, so the resumed run stays bit-identical while the
    tracker re-seeds on the next tested step."""
    cfg = _predict_cfg()
    s = _drive(cfg, init_controller(cfg),
               [(0.02 * k, 1.0) for k in range(1, 12)])
    d = controller_state_as_dict(s)
    old = {k: v for k, v in d.items()
           if not k.startswith(("gns_", "pred_"))}
    restored = controller_state_from_dict(old)
    assert restored.plan == s.plan and restored.step == s.step
    assert restored.ema_stat == s.ema_stat
    assert not restored.gns_init and not restored.gns_slope_init
    assert restored.pred_rung == 0 and restored.pred_eta_steps == -1.0
    # and the zeroed predictor emits the same future PLANS as the populated
    # one on the same continuation stream
    cont = [(0.02 * k, 1.0) for k in range(12, 20)]
    assert _drive(cfg, restored, cont).plan == _drive(cfg, s, cont).plan


def test_predictor_targets_a_reachable_rung():
    """A growing noise stream drives the predicted rung AHEAD of (>=) the
    current plan and onto the ladder; the ETA becomes finite before the
    crossing and 0.0 once the test fires."""
    cfg = _predict_cfg()
    s = init_controller(cfg)
    rungs = {min(p.global_batch, cfg.max_global_batch) for p in cfg.ladder}
    saw_ahead = False
    for k in range(1, 40):
        s = controller_update(cfg, s, 0.004 * k, 1.0)
        if s.at_max:
            break
        if s.gns_init:
            assert s.pred_rung in rungs
            assert s.pred_rung >= s.plan.global_batch
            saw_ahead |= s.pred_rung > s.plan.global_batch
            assert s.pred_eta_steps >= 0.0 or s.pred_eta_steps == -1.0
    assert saw_ahead, "predictor never targeted a rung above the current one"
