"""Algorithm 1 controller: unit + hypothesis property tests."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.controller import (
    ControllerConfig, init_controller, controller_update)
from repro.core.schedule import round_plan


@given(desired=st.integers(1, 10_000_000), workers=st.sampled_from([1, 2, 4, 16, 32]),
       micro=st.sampled_from([1, 2, 4, 8]), max_micro=st.sampled_from([8, 16]),
       accum=st.sampled_from([1, 2, 16]))
@settings(max_examples=200, deadline=None)
def test_round_plan_invariants(desired, workers, micro, max_micro, accum):
    max_global = 8192
    plan = round_plan(desired, workers, micro, max_micro, accum, max_global)
    # Algorithm 1 rounding chain invariants
    assert plan.global_batch == plan.workers * plan.accum_steps * plan.micro_batch
    assert plan.micro_batch <= max_micro
    assert plan.micro_batch >= 1 and plan.accum_steps >= 1
    assert plan.global_batch <= max(max_global, workers * micro)
    if desired <= max_global:
        # rounded result must cover the request
        assert plan.global_batch >= min(desired, max_global) or \
            plan.global_batch + workers * plan.micro_batch > min(desired, max_global)


@given(var=st.floats(0, 1e6, allow_nan=False), gsq=st.floats(1e-6, 1e6),
       eta=st.floats(0.05, 0.9))
@settings(max_examples=100, deadline=None)
def test_controller_monotone_and_clamped(var, gsq, eta):
    cfg = ControllerConfig(eta=eta, workers=4, base_micro_batch=2,
                           max_micro_batch=8, base_accum=2,
                           base_global_batch=16, max_global_batch=1024)
    st_ = init_controller(cfg)
    prev = st_.plan.global_batch
    for _ in range(5):
        st_ = controller_update(cfg, st_, var, gsq)
        assert st_.plan.global_batch >= prev          # monotonic growth
        assert st_.plan.global_batch <= 1024          # clamped
        prev = st_.plan.global_batch


def test_controller_grows_exactly_when_T_exceeds_b():
    cfg = ControllerConfig(eta=0.5, workers=2, base_micro_batch=1,
                           max_micro_batch=1, base_accum=1,
                           base_global_batch=2, max_global_batch=4096)
    s = init_controller(cfg)
    assert s.plan.global_batch == 2
    # T = var/(eta^2 gsq) = 100/(0.25*1) = 400 > 2 -> grow to >= 400
    s = controller_update(cfg, s, var_l1=100.0, grad_sqnorm=1.0)
    assert s.plan.global_batch >= 400
    assert s.plan.global_batch % 2 == 0
    # T below current batch -> keep
    b = s.plan.global_batch
    s = controller_update(cfg, s, var_l1=1e-9, grad_sqnorm=1.0)
    assert s.plan.global_batch == b


def test_at_max_latch_stops_testing():
    cfg = ControllerConfig(eta=0.1, workers=1, base_micro_batch=1,
                           max_micro_batch=1, base_accum=1,
                           base_global_batch=1, max_global_batch=8)
    s = init_controller(cfg)
    s = controller_update(cfg, s, var_l1=1e9, grad_sqnorm=1.0)
    assert s.plan.global_batch == 8 and s.at_max
    s2 = controller_update(cfg, s, var_l1=1e9, grad_sqnorm=1.0)
    assert s2.plan.global_batch == 8


def test_test_interval_skips():
    cfg = ControllerConfig(eta=0.1, workers=1, base_micro_batch=1,
                           max_micro_batch=1, base_accum=1,
                           base_global_batch=1, max_global_batch=1024,
                           test_interval=3)
    s = init_controller(cfg)
    s = controller_update(cfg, s, 1e9, 1.0)   # step 1: skipped (1 % 3 != 0)
    assert s.plan.global_batch == 1
    s = controller_update(cfg, s, 1e9, 1.0)   # step 2: skipped
    assert s.plan.global_batch == 1
    s = controller_update(cfg, s, 1e9, 1.0)   # step 3: tested
    assert s.plan.global_batch > 1
