"""Distributed runtime: multi-device variance statistics (eq. 5) vs brute
force, sharding-spec sanity, mini dry-run — in subprocesses with forced
device counts (the main process keeps 1 device)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.params import param_pspecs, cache_pspecs
from repro.launch.mesh import make_host_mesh
from repro.configs import get_smoke_config
from repro.models import build_model


def test_param_pspecs_divisibility_fallback():
    """internvl2 has 14 heads: head-dim sharding over a 2-wide model axis
    works (14 % 2 == 0) but its kv_heads=2 over 4 would not."""
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = make_host_mesh(data=1, model=1)
    specs = param_pspecs(params, mesh, fsdp=False)
    # single-device mesh: everything must sanitize to replicated
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert all(a is None for a in s), s


def test_fsdp_norm_matches_bruteforce(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.distributed.train_step import make_fsdp_norm_step
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.data.pipeline import MarkovTokens, make_batch
from repro.core.schedule import BatchPlan
from repro.core.norm_test import tree_sqdiff, tree_sqnorm

cfg = get_smoke_config("llama3.2-1b")
model = build_model(cfg)
key = jax.random.PRNGKey(0)
params = model.init(key)
opt = init_adamw(params)
mesh = make_host_mesh(data=4, model=1)
src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
plan = BatchPlan(global_batch=8, micro_batch=2, accum_steps=1, workers=4)
batch = jax.tree.map(jnp.asarray, make_batch(src, 0, plan, 16))
wrap, _, _ = make_fsdp_norm_step(model, AdamWConfig(), mesh, params_like=params)
step = wrap(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
with set_mesh(mesh):
    _, _, metrics = step(params, opt, batch, jnp.float32(1e-3))
params = model.init(key)
gs = []
for j in range(4):
    mb = {k: v[0, j*2:(j+1)*2] for k, v in batch.items()}
    gs.append(jax.grad(lambda p: model.loss(p, mb)[0])(params))
gmean = jax.tree.map(lambda *x: sum(x)/4, *gs)
var_l1 = sum(float(tree_sqdiff(g, gmean)) for g in gs)/4
gsq = float(tree_sqnorm(gmean))
assert abs(var_l1 - float(metrics["var_l1"]))/max(var_l1,1e-9) < 1e-3, (var_l1, float(metrics["var_l1"]))
assert abs(gsq - float(metrics["grad_sqnorm"]))/gsq < 1e-3
print("MATCH")
""", devices=4)
    assert "MATCH" in out


def test_paper_vs_scalar_variance_equal(subproc):
    """The optimized scalar-psum statistic must equal the paper-literal
    full-vector all-reduce formulation (DESIGN §7.1)."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.distributed.train_step import make_fsdp_norm_step
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.data.pipeline import MarkovTokens, make_batch
from repro.core.schedule import BatchPlan

cfg = get_smoke_config("tinyllama-1.1b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = make_host_mesh(data=4, model=1)
src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
plan = BatchPlan(global_batch=8, micro_batch=2, accum_steps=1, workers=4)
batch = jax.tree.map(jnp.asarray, make_batch(src, 0, plan, 16))
sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
vals = {}
for impl in ("scalar", "paper"):
    params_i = model.init(jax.random.PRNGKey(0))   # fresh: steps donate args
    opt = init_adamw(params_i)
    wrap, _, _ = make_fsdp_norm_step(model, AdamWConfig(), mesh,
                                     variance_impl=impl, params_like=params_i)
    with set_mesh(mesh):
        _, _, m = wrap(sds)(params_i, opt, batch, jnp.float32(1e-3))
    vals[impl] = float(m["var_l1"])
assert abs(vals["scalar"] - vals["paper"]) / max(vals["scalar"], 1e-12) < 1e-4, vals
print("EQUAL", vals)
""", devices=4)
    assert "EQUAL" in out


def test_2d_mesh_train_and_serve(subproc):
    """data x model hybrid step + decode step on a 2x2 mesh for a GQA arch
    and an SSM arch."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.distributed.train_step import make_fsdp_norm_step
from repro.distributed.serve_step import make_decode_step
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.data.pipeline import MarkovTokens, make_batch
from repro.core.schedule import BatchPlan

for arch in ("llama3.2-1b", "mamba2-370m"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh(data=2, model=2)
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    plan = BatchPlan(global_batch=8, micro_batch=2, accum_steps=2, workers=2)
    batch = jax.tree.map(jnp.asarray, make_batch(src, 0, plan, 16))
    opt = init_adamw(params)
    wrap, _, _ = make_fsdp_norm_step(model, AdamWConfig(), mesh, params_like=params)
    step = wrap(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
    # build caches OUTSIDE the mesh context so they stay uncommitted and the
    # jitted in_shardings can place them
    dec_wrap, _ = make_decode_step(model, mesh, batch=4, params_like=params)
    cache = model.init_cache(4, 8)
    dstep = dec_wrap(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache))
    tok = jnp.zeros((4,), jnp.int32)
    with set_mesh(mesh):
        p2, o2, m = step(params, opt, batch, jnp.float32(1e-3))
        assert all(float(jnp.isfinite(v)) for v in jax.tree.leaves(m))
        lg, cache = dstep(p2, cache, tok, jnp.int32(0))
        assert bool(jnp.all(jnp.isfinite(lg)))
    print("OK", arch)
""", devices=4)
    assert out.count("OK") == 2


def test_mini_dryrun_all_shapes(subproc):
    """Reduced-scale dry-run: lower+compile train/prefill/decode for a smoke
    config on an 8-device 4x2 mesh (the structural twin of the 512-chip run)."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.distributed.train_step import make_fsdp_norm_step
from repro.distributed.serve_step import make_decode_step, make_prefill
from repro.optim.adamw import AdamWConfig, init_adamw

cfg = get_smoke_config("gemma2-27b").replace(xent_chunk=16)
model = build_model(cfg)
mesh = make_host_mesh(data=4, model=2)
params_like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
opt_like = jax.eval_shape(init_adamw, params_like)
i32 = jnp.int32
with set_mesh(mesh):
    # train
    batch = {"tokens": jax.ShapeDtypeStruct((1, 8, 64), i32),
             "labels": jax.ShapeDtypeStruct((1, 8, 64), i32)}
    wrap, _, _ = make_fsdp_norm_step(model, AdamWConfig(), mesh, params_like=params_like)
    c = wrap(batch).lower(params_like, opt_like, batch,
                          jax.ShapeDtypeStruct((), jnp.float32)).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jaxlib: one dict per device
        ca = ca[0]
    assert ca["flops"] > 0
    # prefill
    pwrap, _ = make_prefill(model, mesh, batch=4, params_like=params_like)
    pb = {"tokens": jax.ShapeDtypeStruct((4, 64), i32)}
    pc = pwrap(pb).lower(params_like, pb).compile()
    # decode
    cache = jax.eval_shape(lambda: model.init_cache(4, 64))
    dwrap, _ = make_decode_step(model, mesh, batch=4, params_like=params_like)
    dc = dwrap(cache).lower(params_like, cache,
                            jax.ShapeDtypeStruct((4,), i32),
                            jax.ShapeDtypeStruct((), i32)).compile()
    print("LOWERED", c.memory_analysis().temp_size_in_bytes >= 0)
""", devices=8)
    assert "LOWERED" in out
