"""input_specs: every (assigned arch x input shape) yields well-formed
ShapeDtypeStructs without allocating (full configs, eval_shape only)."""
import jax
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.shapes import INPUT_SHAPES, input_specs


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_specs_shapes(arch, shape):
    cfg = get_config(arch).replace(dtype="bfloat16", param_dtype="bfloat16")
    spec = input_specs(cfg, shape)
    sh = INPUT_SHAPES[shape]
    if sh.kind == "train":
        assert spec["tokens"].shape[0] == 1                 # accum dim
        assert spec["tokens"].shape[1] == sh.global_batch
        total = spec["tokens"].shape[2] + (
            cfg.frontend.num_prefix_tokens if cfg.frontend.kind == "vision_stub" else 0)
        assert total == sh.seq_len
    elif sh.kind == "prefill":
        assert spec["tokens"].shape[0] == sh.global_batch
    else:
        assert spec["tokens"].shape == (sh.global_batch,)
        leaves = jax.tree.leaves(spec["cache"])
        assert leaves, "cache must be non-empty"
        if shape == "long_500k" and not cfg.native_subquadratic:
            # ring mode: attention caches bounded by the serving window
            max_seq = max(l.shape[-3] for l in leaves if l.ndim >= 3)
            assert max_seq <= max(cfg.long_context_window, 4096 + 1)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_eval_shape_no_alloc(arch):
    cfg = get_config(arch).replace(param_dtype="bfloat16")
    from repro.models import build_model
    model = build_model(cfg)
    tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree.leaves(tree))
    assert abs(n - cfg.param_count()) / cfg.param_count() < 0.05
