"""Deterministic fault-injection harness (DESIGN §12): rule-window
semantics, env arming, per-action behavior, and the engine's transient
warmup-compile retry driven through injected faults."""
import json
import os
import subprocess
import sys
import time

import pytest

from repro.core.schedule import parse_ladder
from repro.data.pipeline import MarkovTokens, make_batch
from repro.distributed.coordination import FileCoordinator
from repro.distributed.engine import BucketedEngine
from repro.testing.faults import (
    FaultInjector, FaultRule, InjectedFault, active, fault_point, inject)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


# ------------------------------------------------------- rule semantics ----

def test_rule_window_fires_exact_invocations():
    """A rule fires on invocations [at, at+count) of ITS site and nowhere
    else — the whole determinism contract in one test."""
    with inject(FaultRule(site="x", at=2, count=2)) as inj:
        fault_point("x")                       # 1: before the window
        for n in (2, 3):                       # 2, 3: inside
            with pytest.raises(InjectedFault, match=f"x\\[{n}\\]"):
                fault_point("x")
        fault_point("x")                       # 4: after the window
        fault_point("y")                       # other sites never fire
        assert inj.invocations("x") == 4
        assert inj.invocations("y") == 1
        assert inj.fired("x") == [("x", 2, "raise"), ("x", 3, "raise")]
        assert inj.fired("y") == []


def test_inject_nests_and_restores():
    assert active() is None
    with inject(FaultRule(site="a")) as outer:
        assert active() is outer
        with inject(FaultRule(site="b")) as inner:
            assert active() is inner
            fault_point("a")                   # outer's rule is NOT armed
            with pytest.raises(InjectedFault):
                fault_point("b")
        assert active() is outer
    assert active() is None


def test_rule_validation():
    with pytest.raises(ValueError, match="unknown fault action"):
        FaultRule(site="x", action="explode")
    with pytest.raises(ValueError, match="at>=1"):
        FaultRule(site="x", at=0)


def test_delay_action_sleeps():
    with inject(FaultRule(site="slow", action="delay", delay_s=0.08)):
        t0 = time.monotonic()
        fault_point("slow")
        assert time.monotonic() - t0 >= 0.06


def test_truncate_action_tears_the_file(tmp_path):
    p = tmp_path / "blob.bin"
    p.write_bytes(b"x" * 100)
    with inject(FaultRule(site="tear", action="truncate", keep_bytes=7)):
        fault_point("tear", path=str(p))
    assert p.stat().st_size == 7
    # a truncate rule at a site that passes no path is a loud setup error
    with inject(FaultRule(site="tear", action="truncate")):
        with pytest.raises(ValueError, match="path"):
            fault_point("tear")


def test_from_env_parses_json_list_and_single_dict(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS",
                       '[{"site": "s", "at": 3, "action": "delay"}]')
    inj = FaultInjector.from_env()
    assert inj.rules == (FaultRule(site="s", at=3, action="delay"),)
    monkeypatch.setenv("REPRO_FAULTS", '{"site": "t"}')
    assert FaultInjector.from_env().rules == (FaultRule(site="t"),)
    monkeypatch.setenv("REPRO_FAULTS", "")
    assert FaultInjector.from_env() is None


def test_die_action_sigkills_subprocess(tmp_path):
    """``die`` is a real SIGKILL (no cleanup, no excepthook) — the process
    exits with signal 9 exactly at the scheduled invocation."""
    code = (
        "from repro.testing.faults import fault_point\n"
        "for i in range(10):\n"
        "    print('tick', i + 1, flush=True)\n"
        "    fault_point('train.step')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["REPRO_FAULTS"] = json.dumps(
        [{"site": "train.step", "at": 3, "action": "die"}])
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=60)
    assert p.returncode == -9, (p.returncode, p.stderr)
    assert p.stdout.splitlines()[-1] == "tick 3"


# ---------------------------------------- engine warmup-compile retry ----

class _FakeJitted:
    def lower(self, *a):
        return self

    def compile(self):
        return lambda *a: None


def _warm_one(coord=None, **engine_kw):
    """One background warmup of rung 2:2 through the retry path; returns
    the engine after drain (caller asserts on stats)."""
    ladder = parse_ladder("2:1,2:2", workers=1)
    eng = BucketedEngine(lambda bl: _FakeJitted(), ladder, params_like={},
                         opt_like={}, aot_warmup=True, coordinator=coord,
                         **engine_kw)
    src = MarkovTokens(vocab_size=32, seed=0)
    eng.warmup(ladder[1], make_batch(src, 0, ladder[0], seq_len=4))
    return eng


def test_transient_warmup_failure_retried_to_success(tmp_path):
    """The acceptance bar: ONE injected compile failure is retried in the
    background and succeeds — no warmup_failure, no fleet broadcast, and
    the rung lands warm."""
    coord = FileCoordinator(str(tmp_path / "c"), 0, 2)
    observer = FileCoordinator(str(tmp_path / "c"), 1, 2)
    with inject(FaultRule(site="engine.warmup_compile", at=1, count=1)) as inj:
        eng = _warm_one(coord=coord, warmup_backoff_s=0.01)
        eng.drain()                                  # would raise if failed
    assert eng.stats.warmups == 1
    assert eng.stats.warmup_retries == 1
    assert eng.stats.warmup_failures == 0
    assert inj.invocations("engine.warmup_compile") == 2   # attempt + retry
    # transient != permanent: nothing was broadcast to the fleet
    assert observer.poll_failures() == frozenset()


def test_permanent_warmup_failure_still_broadcast(tmp_path):
    """A failure outlasting every retry keeps PR 5 semantics: counted once
    at consumption, broadcast to the fleet."""
    coord = FileCoordinator(str(tmp_path / "c"), 0, 2)
    observer = FileCoordinator(str(tmp_path / "c"), 1, 2)
    with inject(FaultRule(site="engine.warmup_compile", at=1, count=99)):
        eng = _warm_one(coord=coord, warmup_retries=2, warmup_backoff_s=0.01)
        with pytest.raises(RuntimeError, match="warmup compile"):
            eng.drain()
    assert eng.stats.warmup_retries == 2             # both retries burned
    assert eng.stats.warmup_failures == 1
    assert len(observer.poll_failures()) == 1        # permanent -> broadcast


def test_warmup_retry_budget_is_configurable():
    with inject(FaultRule(site="engine.warmup_compile", at=1, count=99)):
        eng = _warm_one(warmup_retries=0)            # retries disabled
        with pytest.raises(RuntimeError, match="warmup compile"):
            eng.drain()
    assert eng.stats.warmup_retries == 0
    assert eng.stats.warmup_failures == 1
    assert eng.stats.as_dict()["warmup_retries"] == 0


def test_foreground_compile_site_reaches_lookup():
    """`engine.compile` guards the foreground build: an injected raise
    surfaces to the caller (training would abort — foreground compiles have
    no retry by design; the step cannot proceed without its executable)."""
    ladder = parse_ladder("2:1", workers=1)
    eng = BucketedEngine(lambda bl: (lambda *a: None), ladder)
    src = MarkovTokens(vocab_size=32, seed=0)
    batch = make_batch(src, 0, ladder[0], seq_len=4)
    with inject(FaultRule(site="engine.compile", at=1)):
        with pytest.raises(InjectedFault):
            eng.get_step(batch)
