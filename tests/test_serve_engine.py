"""Continuous-batching serving tier (DESIGN §11): serve controller units,
slot-cache primitives, ServeEngine correctness (solo-equivalence under
continuous batching, slot reuse, staggered joins), rung-reuse cache-hit
accounting, and the 2-device decode-sharding path."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.serve_controller import (
    ServeControllerConfig, init_serve_controller, observe_step_latency,
    serve_controller_update, serve_ladder, quantize_batch)
from repro.launch.mesh import make_host_mesh
from repro.models import build_model

KEY = jax.random.PRNGKey(7)


# ------------------------------------------------------ controller units ----

def test_serve_ladder_shapes():
    assert serve_ladder(8) == (1, 2, 4, 8)
    assert serve_ladder(1) == (1,)
    assert serve_ladder(6) == (1, 2, 4, 6)    # non-power cap is the top rung
    assert quantize_batch(3, (1, 2, 4, 8)) == 4
    assert quantize_batch(100, (1, 2, 4, 8)) == 8


def test_serve_controller_grow_and_shrink_hysteresis():
    cfg = ServeControllerConfig(ladder=(1, 2, 4, 8))
    s = init_serve_controller(cfg)
    # demand above capacity: eager growth, one rung per decision
    s = serve_controller_update(cfg, s, queued=5, active=1)
    assert cfg.ladder[s.rung] == 2
    s = serve_controller_update(cfg, s, queued=4, active=2)
    assert cfg.ladder[s.rung] == 4
    # trough: shrink needs shrink_patience consecutive slack decisions
    for i in range(cfg.shrink_patience - 1):
        s = serve_controller_update(cfg, s, queued=0, active=1)
        assert cfg.ladder[s.rung] == 4, i
    s = serve_controller_update(cfg, s, queued=0, active=1)
    assert cfg.ladder[s.rung] == 2
    # a single demand spike resets the shrink streak
    s2 = serve_controller_update(cfg, s, queued=0, active=1)
    s2 = serve_controller_update(cfg, s2, queued=9, active=1)
    s2 = serve_controller_update(cfg, s2, queued=0, active=1)
    assert s2.shrink_streak == 1


def test_serve_controller_latency_veto_and_ema_seed():
    cfg = ServeControllerConfig(ladder=(1, 2, 4), latency_slo_s=0.1, ema=0.5)
    s = init_serve_controller(cfg)
    # first observation SEEDS the rung EMA (explicit init flag, no blend
    # against the 0.0 placeholder — the training controller's cold-start bug)
    s = observe_step_latency(cfg, s, rung=1, step_time_s=0.4)
    assert s.lat_init[1] and s.lat_ema[1] == pytest.approx(0.4)
    s = observe_step_latency(cfg, s, rung=1, step_time_s=0.2)
    assert s.lat_ema[1] == pytest.approx(0.3)
    # growth into a rung whose measured latency violates the SLO is vetoed
    s = serve_controller_update(cfg, s, queued=5, active=1)
    assert s.rung == 0 and s.latency_vetoes == 1
    # unknown-latency rungs are not vetoed (measure first, judge later)
    cfg2 = ServeControllerConfig(ladder=(1, 2, 4), latency_slo_s=0.1)
    s2 = init_serve_controller(cfg2)
    s2 = serve_controller_update(cfg2, s2, queued=5, active=1)
    assert s2.rung == 1


def test_serve_controller_never_shrinks_below_active():
    cfg = ServeControllerConfig(ladder=(1, 2, 4, 8))
    s = init_serve_controller(cfg)
    s = serve_controller_update(cfg, s, queued=7, active=1)
    s = serve_controller_update(cfg, s, queued=6, active=2)
    assert cfg.ladder[s.rung] == 4
    for _ in range(20):   # 3 active requests never fit rung 2
        s = serve_controller_update(cfg, s, queued=0, active=3)
    assert cfg.ladder[s.rung] == 4


# ------------------------------------------------- slot-cache primitives ----

def test_slot_move_reset_roundtrip():
    from repro.distributed.serve_step import (
        _SLOT_AXIS, _map_slots, move_slot, reset_slot, slice_slots,
        update_slots)
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    cache = model.init_cache(4, 8)

    def fill(x, ax):
        ids = jnp.arange(1, x.shape[ax] + 1, dtype=jnp.float32)
        shape = [1] * x.ndim
        shape[ax] = x.shape[ax]
        return jnp.broadcast_to(ids.reshape(shape), x.shape).astype(x.dtype)

    def slot_vals(c, slot):
        """First element of the given slot row, per leaf."""
        return [float(jnp.take(leaf, slot, axis=_SLOT_AXIS[k]).ravel()[0])
                for k, sub in c.items() for leaf in jax.tree.leaves(sub)]

    filled = _map_slots(cache, fill)
    moved = move_slot(filled, jnp.int32(3), jnp.int32(0))
    # slot 0 now holds slot 3's value; slot 3 itself is unchanged
    assert all(v == 4.0 for v in slot_vals(moved, 0))
    assert all(v == 4.0 for v in slot_vals(moved, 3))
    assert all(v == 2.0 for v in slot_vals(moved, 1))
    wiped = reset_slot(moved, jnp.int32(3))
    assert all(v == 0.0 for v in slot_vals(wiped, 3))
    assert all(v == 4.0 for v in slot_vals(wiped, 0))
    # slice/update round-trip touches rows [0, n) only
    sub = slice_slots(wiped, 2)
    back = update_slots(wiped, jax.tree.map(lambda x: x * 0 - 1, sub), 2)
    assert all(v == -1.0 for v in slot_vals(back, 0))
    assert all(v == -1.0 for v in slot_vals(back, 1))
    assert slot_vals(back, 2) == slot_vals(wiped, 2)


# ----------------------------------------------------------- the engine ----

def _engine(arch="llama3.2-1b", max_slots=4, cache_len=16, **kw):
    from repro.distributed.serve_engine import ServeEngine
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    mesh = make_host_mesh(1, 1)
    eng = ServeEngine(model, params, mesh, max_slots=max_slots,
                      cache_len=cache_len, **kw)
    return cfg, model, params, eng


def _solo_greedy(model, params, prompt, max_new, cache_len):
    """Reference: one request decoded alone in a fresh batch-1 cache."""
    cache = model.init_cache(1, cache_len)
    out = []
    for i in range(len(prompt) + max_new - 1):
        t = prompt[i] if i < len(prompt) else out[-1]
        lg, cache = model.decode_step(params, cache,
                                      jnp.asarray([t], jnp.int32),
                                      jnp.int32(i))
        nt = int(jnp.argmax(lg[0]))
        if i >= len(prompt) - 1:
            out.append(nt)
    return out


def test_engine_matches_solo_decode_and_reuses_slots():
    """Requests batched continuously (joining/leaving mid-flight, slots
    compacted and reused) must generate EXACTLY what each would alone —
    the slot-residency invariant: stale KV above a row's pos is never
    attended, every position is overwritten before it is read."""
    cfg, model, params, eng = _engine()
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=(3,)).astype(np.int32)
               for _ in range(5)]
    reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
    done = eng.run_until_drained()
    assert len(done) == 5
    for r in reqs:
        assert r.generated == _solo_greedy(model, params, list(r.prompt), 4,
                                           eng.cache_len), r.rid
    # second wave into RECYCLED slots (no cache realloc, rows were used by
    # wave 1) must match wave 1 token-for-token
    reqs2 = [eng.submit(p, max_new_tokens=4) for p in prompts[:2]]
    eng.run_until_drained()
    for a, b in zip(reqs, reqs2):
        assert a.generated == b.generated
    assert eng.stats.slot_resets == 7
    assert eng.stats.requests_completed == 7


def test_engine_staggered_joins_match_solo():
    """A request admitted while others are mid-generation (joining a
    half-used batch at pos 0 while neighbors sit at pos > 0) decodes as if
    it were alone — per-slot position vectors keep every timeline honest."""
    cfg, model, params, eng = _engine(max_slots=4)
    rng = np.random.RandomState(1)
    p1 = rng.randint(0, cfg.vocab_size, size=(4,)).astype(np.int32)
    p2 = rng.randint(0, cfg.vocab_size, size=(2,)).astype(np.int32)
    r1 = eng.submit(p1, max_new_tokens=6)
    for _ in range(3):
        eng.step()                      # r1 is mid-prefill/decode
    r2 = eng.submit(p2, max_new_tokens=5)   # joins a live batch
    eng.run_until_drained()
    assert r1.generated == _solo_greedy(model, params, list(p1), 6,
                                        eng.cache_len)
    assert r2.generated == _solo_greedy(model, params, list(p2), 5,
                                        eng.cache_len)


def test_engine_rung_growth_hits_warmed_cache():
    """The tentpole's acceptance shape: warm the ladder, then force a
    request-batch-size change at steady state — the rung transition must be
    a cache HIT (transition_hits) with ZERO new compiles."""
    cfg, model, params, eng = _engine(max_slots=4, aot_warmup=True)
    rng = np.random.RandomState(2)
    eng.warm(eng.ladder)
    eng.drain(raise_errors=True)
    assert eng.stats.warmups == len(eng.ladder)
    compiles0 = eng.stats.compiles
    for _ in range(4):                  # demand 4 forces rung 1 -> 2 -> 4
        eng.submit(rng.randint(0, cfg.vocab_size, size=(2,)).astype(np.int32),
                   max_new_tokens=3)
    eng.run_until_drained()
    assert eng.stats.compiles == compiles0          # zero foreground builds
    assert eng.stats.rung_transitions >= 1
    assert eng.stats.transition_hits == eng.stats.rung_transitions
    assert eng.stats.hit_rate == 1.0


def test_engine_cold_transition_counts_miss():
    """Without warmup, a rung change compiles in the foreground and is NOT
    counted as a transition hit — the accounting distinguishes the two."""
    cfg, model, params, eng = _engine(max_slots=4, aot_warmup=False)
    rng = np.random.RandomState(3)
    for _ in range(4):
        eng.submit(rng.randint(0, cfg.vocab_size, size=(2,)).astype(np.int32),
                   max_new_tokens=3)
    eng.run_until_drained()
    assert eng.stats.rung_transitions >= 1
    assert eng.stats.transition_hits == 0
    assert eng.stats.warmups == 0
    assert eng.stats.compiles >= 2


def test_engine_submit_validation():
    cfg, model, params, eng = _engine(cache_len=8)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(np.zeros((5,), np.int32), max_new_tokens=4)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs 2 host devices (tests-multidevice job)")
def test_engine_two_device_decode_sharding():
    """On a (2, 1) data mesh the resident cache and per-step token vectors
    shard over the data axis (max_slots % workers == 0) and results still
    match the solo reference."""
    from repro.distributed.serve_engine import ServeEngine
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(KEY)
    mesh = make_host_mesh(2, 1)
    eng = ServeEngine(model, params, mesh, max_slots=4, cache_len=16)
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, size=(3,)).astype(np.int32)
               for _ in range(4)]
    reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
    eng.run_until_drained()
    for r in reqs:
        assert r.generated == _solo_greedy(model, params, list(r.prompt), 4,
                                           eng.cache_len)
    # the resident pool is genuinely sharded over the data axis
    leaf = jax.tree.leaves(eng._kv)[0]
    assert len(leaf.sharding.device_set) == 2


# ----------------------------------------------- admission control ----

def test_max_queue_rejects_with_typed_error_and_counts():
    """Overload is load-shed at submit: the max_queue+1'th waiting request
    gets a typed `QueueFullError` (never enqueued, counted in
    `requests_rejected`); draining frees capacity again."""
    from repro.distributed.serve_engine import QueueFullError
    cfg, model, params, eng = _engine(max_slots=2, max_queue=3)
    prompt = np.array([1, 2], np.int32)
    for _ in range(3):
        eng.submit(prompt, max_new_tokens=2)
    with pytest.raises(QueueFullError) as ei:
        eng.submit(prompt, max_new_tokens=2)
    assert ei.value.queued == 3 and ei.value.max_queue == 3
    assert "max_queue 3" in str(ei.value)
    assert eng.stats.requests_rejected == 1
    assert eng.stats.requests_submitted == 3       # the reject never counted
    assert len(eng.queue) == 3                     # ...and never enqueued
    # malformed requests are ValueError, not rejection accounting
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.array([], np.int32), max_new_tokens=1)
    assert eng.stats.requests_rejected == 1
    eng.run_until_drained()
    eng.submit(prompt, max_new_tokens=2)           # capacity is back
    done = eng.run_until_drained()
    assert eng.stats.requests_completed == 4 and len(done) == 1
    assert eng.stats.as_dict()["requests_rejected"] == 1


def test_max_queue_zero_is_unbounded_and_negative_rejected():
    from repro.distributed.serve_engine import ServeEngine
    cfg, model, params, eng = _engine(max_slots=2)     # default: unbounded
    assert eng.max_queue == 0
    prompt = np.array([1], np.int32)
    for _ in range(50):
        eng.submit(prompt, max_new_tokens=1)
    assert eng.stats.requests_rejected == 0
    mesh = make_host_mesh(1, 1)
    with pytest.raises(ValueError, match="max_queue"):
        ServeEngine(model, params, mesh, max_slots=2, cache_len=16,
                    max_queue=-1)
