"""Unit tests for the paper's core statistic (eq. 3/4/5 estimators)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.norm_test import (
    per_sample_norm_test, accum_variance_stats, exact_variance_test_holds,
    tree_sqnorm, tree_sqdiff)


def quad_loss(params, example):
    # per-example loss: ||w - x||^2 => per-sample grads 2(w - x)
    return jnp.sum((params["w"] - example) ** 2)


def test_per_sample_norm_test_matches_manual():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal(5), jnp.float32)
    xs = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
    res = per_sample_norm_test(quad_loss, {"w": w}, xs, eta=0.5)
    grads = np.stack([2 * (np.asarray(w) - np.asarray(x)) for x in xs])
    gmean = grads.mean(0)
    var_l1 = ((grads - gmean) ** 2).sum() / (len(xs) - 1)
    np.testing.assert_allclose(res["var_l1"], var_l1, rtol=1e-5)
    np.testing.assert_allclose(res["grad_sqnorm"], (gmean ** 2).sum(), rtol=1e-5)
    np.testing.assert_allclose(
        res["T"], var_l1 / (0.25 * (gmean ** 2).sum() + 1e-30), rtol=1e-5)


def test_exact_variance_test_zero_noise():
    # identical per-sample grads -> zero variance -> test holds for any eta
    ps = {"w": jnp.ones((4, 3))}
    assert bool(exact_variance_test_holds(ps, eta=0.01))


def test_exact_variance_test_pure_noise():
    # zero-mean grads -> infinite relative variance -> test must fail
    ps = {"w": jnp.asarray([[1.0, -1.0], [-1.0, 1.0]])}
    assert not bool(exact_variance_test_holds(ps, eta=0.9))


def test_accum_variance_unbiased_scale():
    """ACCUM-NORM's rescale maps microbatch variance onto eq.(5)'s per-worker
    scale: for i.i.d. grads g^m ~ N(mu, s^2 I / micro_size) the estimator
    should approximate J * tr(Sigma_ps)/b = J*s^2*d/b."""
    rng = np.random.default_rng(1)
    d, M, J, reps = 50, 8, 4, 400
    s2 = 4.0
    ests = []
    for r in range(reps):
        micro = jnp.asarray(rng.standard_normal((M, d)) * np.sqrt(s2), jnp.float32)
        # micro grads already "data-averaged"; mean grad:
        g = {"w": jnp.mean(micro, 0)}
        sq_sum = jnp.sum(jnp.sum(micro ** 2, -1))
        var_l1, _ = accum_variance_stats(sq_sum, g, M, J)
        ests.append(float(var_l1))
    # E[var_l1] = (J/M) * E[V_m] = (J/M) * s2*d
    expect = J / M * s2 * d
    assert abs(np.mean(ests) - expect) / expect < 0.1


def test_tree_helpers():
    a = {"x": jnp.ones((3,)), "y": jnp.zeros((2, 2))}
    b = {"x": jnp.zeros((3,)), "y": jnp.ones((2, 2))}
    assert float(tree_sqnorm(a)) == 3.0
    assert float(tree_sqdiff(a, b)) == 7.0
