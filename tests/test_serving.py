"""Serving-path tests: ring-window equivalence, whisper enc-dec decode vs
teacher forcing, VLM prefix decode vs forward, serve driver smoke."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.transformer import encode
from repro.models.attention import precompute_cross_kv

KEY = jax.random.PRNGKey(5)


def test_ring_equals_full_before_wrap():
    """While pos < ring length, ring-buffer decode must equal full-cache
    decode exactly."""
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(KEY)
    b, steps, ring_len = 2, 6, 8
    tokens = jax.random.randint(KEY, (b, steps), 0, cfg.vocab_size)
    full = model.init_cache(b, steps)
    ring = model.init_cache(b, ring_len, ring=True)
    for i in range(steps):
        lf, full = model.decode_step(params, full, tokens[:, i], jnp.int32(i))
        lr, ring = model.decode_step(params, ring, tokens[:, i], jnp.int32(i),
                                     ring=True)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr),
                                   rtol=1e-5, atol=1e-5)


def test_whisper_decode_matches_teacher_forcing():
    cfg = get_smoke_config("whisper-base")
    model = build_model(cfg)
    params = model.init(KEY)
    b, t = 2, 12
    frames = jax.random.normal(KEY, (b, cfg.encoder.num_frames, cfg.d_model))
    tokens = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens, "frames": frames}
    full_logits = model.logits(params, batch)

    # build decode cache with cross-kv precomputed from the encoder
    cache = model.init_cache(b, t)
    enc = encode(params, frames.astype(cfg.act_dtype), cfg)
    cross = [precompute_cross_kv(p["cross_attn"], enc)
             for p in params.get("prefix_blocks", [])]
    cache["cross_prefix"] = cross
    # scanned blocks: stack per-repeat cross kv
    reps = cfg.num_repeats
    per_pos = []
    for bp in params["blocks"]:
        kvs = [precompute_cross_kv(
            jax.tree.map(lambda a: a[r], bp)["cross_attn"], enc)
            for r in range(reps)]
        per_pos.append(jax.tree.map(lambda *xs: jnp.stack(xs), *kvs))
    cache["cross_scanned"] = per_pos

    outs = []
    for i in range(t):
        lg, cache = model.decode_step(params, cache, tokens[:, i], jnp.int32(i))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=3e-3, atol=3e-3)


def test_vlm_decode_continues_prefix():
    """VLM: forward over (patches + text) vs decode over text with the
    patch prefix streamed through the cache first."""
    cfg = get_smoke_config("internvl2-1b")
    model = build_model(cfg)
    params = model.init(KEY)
    b = 2
    npfx = cfg.frontend.num_prefix_tokens
    t = 8
    patches = jax.random.normal(KEY, (b, npfx, cfg.d_model))
    tokens = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens, "patch_embeds": patches}
    full_logits = model.logits(params, batch)          # (b, t, v) text part
    assert full_logits.shape == (b, t, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(full_logits)))


def test_serve_driver_smoke():
    from repro.launch.serve import run_serving
    res = run_serving("llama3.2-1b", smoke=True, batch=2, prompt_len=8,
                      gen_len=8)
    assert res["tokens"].shape == (2, 8)
    assert res["decode_tok_per_s"] > 0


def test_serve_driver_accounting():
    """Regressions for the serving-driver timing/throughput fixes: the
    decode timer sees gen_len - 1 tokens per sequence (the first generated
    token falls out of the prefill phase), and the throughput numerator
    must match — the old batch * gen_len overstated tok/s."""
    from repro.launch.serve import run_serving
    batch, gen_len = 2, 8
    res = run_serving("llama3.2-1b", smoke=True, batch=batch, prompt_len=4,
                      gen_len=gen_len)
    assert res["decode_tokens_timed"] == batch * (gen_len - 1)
    assert res["decode_tok_per_s"] == pytest.approx(
        res["decode_tokens_timed"] / res["decode_s"])


def test_serve_driver_gen_len_one():
    """gen_len=1: the only generated token comes from prefill; the decode
    loop runs zero iterations and throughput must report 0, not divide a
    phantom batch*1 tokens by an ~0 timer."""
    from repro.launch.serve import run_serving
    res = run_serving("llama3.2-1b", smoke=True, batch=2, prompt_len=4,
                      gen_len=1)
    assert res["tokens"].shape == (2, 1)
    assert res["decode_tokens_timed"] == 0
    assert res["decode_tok_per_s"] == 0.0


def test_serve_driver_blocks_before_prefill_clock(monkeypatch):
    """The prefill timer must fence the async dispatch: block_until_ready
    runs before each phase clock is read, so prefill compute cannot leak
    into the decode measurement."""
    import repro.launch.serve as serve_mod
    calls = []
    real = jax.block_until_ready

    def spy(x):
        calls.append(time.time())
        return real(x)

    monkeypatch.setattr(serve_mod.jax, "block_until_ready", spy)
    serve_mod.run_serving("llama3.2-1b", smoke=True, batch=1, prompt_len=2,
                          gen_len=2)
    assert len(calls) >= 2   # one fence per timed phase


def test_serve_driver_vision_prompt_too_short():
    """vision_stub edge: a prompt budget fully consumed by the frontend's
    prefix tokens must fail with a clear error, not crash on prompts[:, 0]."""
    from repro.configs import get_smoke_config
    from repro.launch.serve import run_serving
    cfg = get_smoke_config("internvl2-1b")
    npfx = cfg.frontend.num_prefix_tokens
    with pytest.raises(ValueError, match="prefix tokens"):
        run_serving("internvl2-1b", smoke=True, batch=1, prompt_len=npfx,
                    gen_len=2)
