"""Layer-2 (AST lint) tests: every rule fires on its fixture at the right
line with the right id, waivers suppress without hiding, and the real repo
is lint-clean (the CI gate's invariant).

Fixtures live in tests/fixtures/lint/ mirroring the repo layout so
path-scoped rules apply; violation lines are located by content marker, not
hard-coded line numbers.
"""

import pathlib

import pytest

from repro.analysis import lint_file, rules, run_lint
from repro.analysis.lint import WAIVER_RE

FIXROOT = pathlib.Path(__file__).parent / "fixtures" / "lint"
REPO = pathlib.Path(__file__).parent.parent


def _marked_lines(path: pathlib.Path, marker: str) -> list[int]:
    return [i for i, line in enumerate(path.read_text().splitlines(), 1)
            if marker in line]


def _lint(rel: str):
    return lint_file(FIXROOT / rel, root=FIXROOT)


FIXTURES = {
    "hash-seed": "src/repro/core/hash_cache.py",
    "wallclock-traced": "src/repro/kernels/clocked.py",
    "host-divergence": "src/repro/models/rank_branch.py",
    "bare-interpret": "src/repro/kernels/pinned.py",
    "set-iter-order": "src/repro/core/set_order.py",
    "unfenced-timing": "benchmarks/leaky.py",
    "nonatomic-write": "src/repro/checkpoint/torn.py",
}


@pytest.mark.parametrize("rule_id", sorted(FIXTURES))
def test_rule_fires_at_marked_lines(rule_id):
    """Each rule flags exactly the `# VIOLATION <rule>` lines of its
    fixture (id + line), and nothing else unwaived."""
    path = FIXROOT / FIXTURES[rule_id]
    expected = _marked_lines(path, f"# VIOLATION {rule_id}")
    assert expected, f"fixture {path} has no marked violations"
    active = [f for f in _lint(FIXTURES[rule_id]) if not f.waived]
    assert [f.rule for f in active] == [rule_id] * len(expected)
    got_lines = sorted(int(f.location.rsplit(":", 1)[1]) for f in active)
    assert got_lines == expected, (rule_id, got_lines, expected)


def test_planted_hash_seeded_cache_key_is_flagged():
    """Acceptance criterion: the planted hash()-seeded cache key (the PR 5
    desync class) is caught, and id() is caught by the same rule."""
    msgs = [f.message for f in _lint(FIXTURES["hash-seed"]) if not f.waived]
    assert any("hash()" in m for m in msgs)
    assert any("id()" in m for m in msgs)


def test_waiver_suppresses_but_stays_in_report():
    """A `# repro: allow(...)` on the line or the line above marks the
    finding waived (never gates) while keeping it visible in the report,
    reason attached."""
    for rel in (FIXTURES["hash-seed"], FIXTURES["wallclock-traced"]):
        waived = [f for f in _lint(rel) if f.waived]
        assert len(waived) == 1, rel
        assert waived[0].waiver_reason.startswith("fixture")
        assert "waived" in waived[0].render()


def test_exemptions_do_not_fire():
    """Rule exemptions hold: hash() inside __hash__, a fenced timing span,
    a single clock read, sorted(set(...)) iteration, and a
    fsync+os.replace writer all pass clean."""
    hash_src = (FIXROOT / FIXTURES["hash-seed"]).read_text().splitlines()
    in_hash_proto = next(i for i, l in enumerate(hash_src, 1)
                         if "hash(self.inner)" in l)
    for rel in FIXTURES.values():
        for f in _lint(rel):
            line = int(f.location.rsplit(":", 1)[1])
            src_line = (FIXROOT / rel).read_text().splitlines()[line - 1]
            assert "# clean" not in src_line, f.render()
            if rel == FIXTURES["hash-seed"]:
                assert line != in_hash_proto, "__hash__ body must be exempt"


def test_scope_gates_path_scoped_rules():
    """The same hazardous source OUTSIDE a rule's path scope produces no
    finding — wall-clock reads are only findings in traced-code paths."""
    src = (FIXROOT / FIXTURES["wallclock-traced"]).read_text()
    elsewhere = FIXROOT / "src" / "repro" / "launch" / "clocked_copy.py"
    elsewhere.parent.mkdir(parents=True, exist_ok=True)
    elsewhere.write_text(src)
    try:
        found = [f for f in lint_file(elsewhere, root=FIXROOT)
                 if f.rule == "wallclock-traced"]
        assert not found, "launch/ is outside the traced-code scope"
    finally:
        elsewhere.unlink()


def test_waiver_regex_shapes():
    """The waiver grammar: one id, a comma list, `all`, optional reason."""
    m = WAIVER_RE.search("x()  # repro: allow(hash-seed) — legacy key")
    assert m and m.group(1) == "hash-seed" and m.group(2) == "legacy key"
    m = WAIVER_RE.search("# repro: allow(hash-seed, set-iter-order)")
    assert m and set(m.group(1).split(", ")) == {"hash-seed",
                                                 "set-iter-order"}
    assert WAIVER_RE.search("# repro: allow(all) - everything")
    assert not WAIVER_RE.search("# repro allow(hash-seed)")


def test_rule_registry_covers_issue_catalog():
    """Every lint rule (the six DESIGN §13 originals plus §15's
    host-divergence) is registered, each with a docstring (the report/docs
    surface)."""
    by_id = {r.id for r in rules()}
    assert by_id == set(FIXTURES)
    assert all(r.doc for r in rules())


def test_repo_is_lint_clean():
    """THE gate invariant: the real src/ + benchmarks/ trees carry zero
    unwaived findings (intentional hits are waived inline with reasons)."""
    findings = run_lint(REPO)
    active = [f for f in findings if not f.waived]
    assert not active, "\n".join(f.render() for f in active)
    # the waivers that do exist all carry a reason
    assert all(f.waiver_reason for f in findings if f.waived)
