"""GNS estimators: consistency with the norm-test statistics on synthetic
gradients with known noise scale."""
import math

import numpy as np
import pytest

from repro.core.gns import (
    GNSTracker, critical_gns_at, gns_from_norm_test, predict_target_batch,
    rung_crossing_eta, unbiased_gns_pair, variance_groups)


def synthetic_stats(b, J, d, mu, sigma, seed=0, reps=2000):
    """Simulate worker gradients g_j = mu + noise/sqrt(b/J) and return the
    eq.(5) statistics averaged over reps."""
    rng = np.random.default_rng(seed)
    b_w = b // J
    var_l1s, gsqs = [], []
    for _ in range(reps):
        gj = mu[None] + rng.standard_normal((J, d)) * sigma / np.sqrt(b_w)
        g = gj.mean(0)
        var_l1s.append(((gj - g) ** 2).sum(1).mean())
        gsqs.append((g ** 2).sum())
    return float(np.mean(var_l1s)), float(np.mean(gsqs))


def test_point_estimate_recovers_noise_scale():
    d, b, J = 16, 64, 8
    mu = np.ones(d) * 0.5
    sigma = 2.0
    var_l1, gsq = synthetic_stats(b, J, d, mu, sigma)
    est = gns_from_norm_test(var_l1, gsq, b, J)
    true_tr_sigma = d * sigma**2
    # E var_l1 = tr(Sigma)/b_w * (1 - 1/J); accept the (1-1/J) bias envelope
    assert true_tr_sigma * 0.7 < est["tr_sigma"] < true_tr_sigma * 1.1


def test_unbiased_pair_beats_point_estimate():
    d, b, J = 16, 64, 8
    mu = np.ones(d) * 0.5
    sigma = 2.0
    var_l1, gsq = synthetic_stats(b, J, d, mu, sigma, reps=4000)
    est = unbiased_gns_pair(var_l1, gsq, b, J)
    true_b_simple = d * sigma**2 / (mu ** 2).sum()
    assert abs(est["b_simple"] - true_b_simple) / true_b_simple < 0.15


def test_tracker_converges():
    t = GNSTracker(alpha=0.5)
    for _ in range(20):
        t = t.update(var_l1=4.0, grad_sqnorm=1.0, global_batch=64, workers=8)
    pair = unbiased_gns_pair(4.0, 1.0, 64, 8)
    assert abs(t.b_simple - pair["b_simple"]) < 1e-6


# ----------------------------------------------------- variance groups ----

def test_variance_groups():
    assert variance_groups("fsdp_norm", 8) == 8
    assert variance_groups("fsdp_norm", 8, accum_steps=4) == 8
    assert variance_groups("accum_norm", 1, accum_steps=4) == 4
    assert variance_groups("accum_norm", 2, accum_steps=4) == 8
    # degenerate inputs clamp to one group, never zero
    assert variance_groups("fsdp_norm", 0) == 1
    assert variance_groups("accum_norm", 0, accum_steps=0) == 1


def test_accum_norm_single_worker_gns_is_alive():
    """Regression: with workers=1 the old estimator degenerated to
    b_small == b_big and silently returned b_simple = 0 — every ACCUM-NORM
    J=1 run had a dead GNS signal.  Passing the M·J group count revives it
    and matches the J=M FSDP-Norm estimate on identical statistics."""
    d, b, m = 16, 64, 8
    mu = np.ones(d) * 0.5
    sigma = 2.0
    var_l1, gsq = synthetic_stats(b, m, d, mu, sigma, reps=4000)
    # old call shape: J=1 and no groups -> clamped dead signal, flagged
    dead = unbiased_gns_pair(var_l1, gsq, b, 1)
    assert dead["b_simple"] == 0.0 and not dead["valid"]
    # var_l1 simulated on the J=m scale; feeding workers=m groups=m matches
    # the FSDP case, workers=1 with var rescaled to the J=1 scale agrees
    alive = unbiased_gns_pair(var_l1, gsq, b, m, groups=m)
    ref = unbiased_gns_pair(var_l1, gsq, b, m)
    assert alive["valid"]
    assert abs(alive["b_simple"] - ref["b_simple"]) < 1e-9
    rescaled = unbiased_gns_pair(var_l1 / m, gsq, b, 1, groups=m)
    assert abs(rescaled["b_simple"] - ref["b_simple"]) < 1e-9


def test_unbiased_pair_clamps_degenerate_estimates():
    # g2 <= 0 (noise swamps the mean gradient): clamped to 0.0, not inf/neg
    est = unbiased_gns_pair(var_l1=100.0, grad_sqnorm=1e-12, global_batch=64,
                            workers=8)
    assert not est["valid"]
    assert est["b_simple"] == 0.0
    assert math.isfinite(est["b_simple"])
    # one group: no two-scale signal at all
    est = unbiased_gns_pair(4.0, 1.0, 64, 1)
    assert not est["valid"] and est["b_simple"] == 0.0


def test_tracker_skips_invalid_and_seeds_first_valid():
    t = GNSTracker(alpha=0.5)
    # invalid observations never touch the EMAs
    t2 = t.update(var_l1=100.0, grad_sqnorm=1e-12, global_batch=64, workers=8)
    assert t2 is t and not t2.initialized and t2.b_simple == 0.0
    # the first VALID observation SEEDS (no blend against 0.0 placeholders)
    t3 = t2.update(var_l1=4.0, grad_sqnorm=1.0, global_batch=64, workers=8)
    pair = unbiased_gns_pair(4.0, 1.0, 64, 8)
    assert t3.initialized
    assert abs(t3.s_ema - pair["s"]) < 1e-12
    assert abs(t3.g2_ema - pair["g2"]) < 1e-12
    # subsequent observations BLEND
    pair2 = unbiased_gns_pair(2.0, 1.0, 64, 8)
    assert pair2["valid"]
    t4 = t3.update(var_l1=2.0, grad_sqnorm=1.0, global_batch=64, workers=8)
    assert abs(t4.s_ema - (0.5 * pair["s"] + 0.5 * pair2["s"])) < 1e-12


# --------------------------------------------------------- prediction ----

def test_critical_gns_levels():
    # eta=0.12, J=1: the test can fire at 4..64 but never at 128
    # (J <= eta^2 * b) — values from the DESIGN §14 derivation
    assert abs(critical_gns_at(4, 0.12, 1) - 0.2445) < 1e-3
    assert abs(critical_gns_at(32, 0.12, 1) - 27.347) < 1e-2
    assert critical_gns_at(128, 0.12, 1) == float("inf")
    # monotone in b on the crossable range
    levels = [critical_gns_at(b, 0.12, 1) for b in (4, 8, 16, 32, 64)]
    assert levels == sorted(levels)


def test_rung_crossing_eta():
    cross = critical_gns_at(8, 0.12, 1)
    # already above the crossing level: fires now
    assert rung_crossing_eta(cross + 1.0, 0.5, 8, 0.12, 1) == 0.0
    # below with positive slope: linear ETA
    eta = rung_crossing_eta(cross - 1.0, 0.5, 8, 0.12, 1)
    assert abs(eta - 2.0) < 1e-9
    # flat/shrinking GNS, or an uncrossable rung: -1.0 sentinel (JSON-safe)
    assert rung_crossing_eta(cross - 1.0, 0.0, 8, 0.12, 1) == -1.0
    assert rung_crossing_eta(1.0, 0.5, 128, 0.12, 1) == -1.0


def test_predict_target_batch():
    rungs = (4, 8, 16, 32, 64)
    # low projected GNS: already stable at the current rung
    assert predict_target_batch(0.1, 0.0, 5, 4, 0.12, 1, rungs) == 4
    # projection above B_cross(4)≈0.24 but under B_cross(8)≈1.04 -> rung 8
    assert predict_target_batch(0.5, 0.0, 5, 4, 0.12, 1, rungs) == 8
    # growing: 0.5 + 5*0.5 = 3.0 sits between B_cross(8) and B_cross(16)
    assert predict_target_batch(0.5, 0.5, 5, 4, 0.12, 1, rungs) == 16
    # projection above every crossing level -> top rung
    assert predict_target_batch(1e9, 0.0, 5, 4, 0.12, 1, rungs) == 64
    # never predicts below the current rung
    assert predict_target_batch(0.1, 0.0, 5, 16, 0.12, 1, rungs) == 16
    # no ladder -> nothing to predict onto
    assert predict_target_batch(0.5, 0.0, 5, 4, 0.12, 1, None) == 0
