"""GNS estimators: consistency with the norm-test statistics on synthetic
gradients with known noise scale."""
import numpy as np
import pytest

from repro.core.gns import gns_from_norm_test, unbiased_gns_pair, GNSTracker


def synthetic_stats(b, J, d, mu, sigma, seed=0, reps=2000):
    """Simulate worker gradients g_j = mu + noise/sqrt(b/J) and return the
    eq.(5) statistics averaged over reps."""
    rng = np.random.default_rng(seed)
    b_w = b // J
    var_l1s, gsqs = [], []
    for _ in range(reps):
        gj = mu[None] + rng.standard_normal((J, d)) * sigma / np.sqrt(b_w)
        g = gj.mean(0)
        var_l1s.append(((gj - g) ** 2).sum(1).mean())
        gsqs.append((g ** 2).sum())
    return float(np.mean(var_l1s)), float(np.mean(gsqs))


def test_point_estimate_recovers_noise_scale():
    d, b, J = 16, 64, 8
    mu = np.ones(d) * 0.5
    sigma = 2.0
    var_l1, gsq = synthetic_stats(b, J, d, mu, sigma)
    est = gns_from_norm_test(var_l1, gsq, b, J)
    true_tr_sigma = d * sigma**2
    # E var_l1 = tr(Sigma)/b_w * (1 - 1/J); accept the (1-1/J) bias envelope
    assert true_tr_sigma * 0.7 < est["tr_sigma"] < true_tr_sigma * 1.1


def test_unbiased_pair_beats_point_estimate():
    d, b, J = 16, 64, 8
    mu = np.ones(d) * 0.5
    sigma = 2.0
    var_l1, gsq = synthetic_stats(b, J, d, mu, sigma, reps=4000)
    est = unbiased_gns_pair(var_l1, gsq, b, J)
    true_b_simple = d * sigma**2 / (mu ** 2).sum()
    assert abs(est["b_simple"] - true_b_simple) / true_b_simple < 0.15


def test_tracker_converges():
    t = GNSTracker(alpha=0.5)
    for _ in range(20):
        t = t.update(var_l1=4.0, grad_sqnorm=1.0, global_batch=64, workers=8)
    pair = unbiased_gns_pair(4.0, 1.0, 64, 8)
    assert abs(t.b_simple - pair["b_simple"]) < 1e-6
