"""Validating the testable consequences of the paper's §4 theory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, st

from repro.core.theory import (
    coordinate_norm_test_holds, esg_constant, adam_beta_condition,
    minimal_batch_for_coordinate_test)


def gaussian_per_sample_grads(key, n, d, mu_scale=1.0, noise=0.1):
    mu = mu_scale * jax.random.normal(key, (d,))
    eps = noise * jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    return {"w": mu[None] + eps}


def test_proposition1_esg_bound():
    """Prop. 1: if the coordinate-wise exact-variance test holds with eta,
    the coordinate-wise E-SG constant is <= 1 + eta^2."""
    key = jax.random.PRNGKey(0)
    grads = gaussian_per_sample_grads(key, n=4096, d=32, noise=0.05)
    eta = 0.5
    b = 64
    if bool(coordinate_norm_test_holds(grads, eta, b)):
        c = float(esg_constant(grads, b))
        assert c <= 1 + eta**2 + 1e-6


def test_minimal_batch_enforces_test():
    key = jax.random.PRNGKey(1)
    grads = gaussian_per_sample_grads(key, n=8192, d=16, noise=0.3)
    eta = 0.4
    b_star = int(minimal_batch_for_coordinate_test(grads, eta))
    assert b_star >= 1
    assert bool(coordinate_norm_test_holds(grads, eta, b_star))
    if b_star > 1:
        assert not bool(coordinate_norm_test_holds(grads, eta, max(b_star // 4, 1)))


def test_adam_beta_condition_paper_defaults():
    """The paper's own training betas (0.9, 0.95) VIOLATE Theorem 1's
    sufficient condition — the constants are conservative (documented in
    core/theory.py and DESIGN.md); the condition does hold for larger beta2."""
    res = adam_beta_condition(0.9, 0.95, eta=0.2)
    assert not res["holds"]
    res2 = adam_beta_condition(0.9, 0.999, eta=0.2)
    assert res2["holds"], res2


@given(beta2=st.floats(0.9, 0.99999), eta=st.floats(0.01, 0.9))
@settings(max_examples=50, deadline=None)
def test_beta_bound_monotone_in_eta(beta2, eta):
    b1 = adam_beta_condition(0.5, beta2, eta)["beta1_bound"]
    b2 = adam_beta_condition(0.5, beta2, eta + 0.05)["beta1_bound"]
    assert b2 <= b1 + 1e-12   # noisier gradients -> stricter beta1
