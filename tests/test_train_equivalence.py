"""Differential oracle (DESIGN §10 acceptance): every {stats_impl} ×
{params_impl} residency combination of both distributed train steps must
reproduce the tree/tree reference trajectory — per-step loss, var_l1,
grad_sqnorm, clip_scale, and the final parameters — to ≤1e-5 over 5 steps
on the same seed and batch stream.

The tree/tree path is the oracle; flat-resident params (gradients born
flat through `unflatten_for_grad`) and the fused flat statistics tail must
be numerically invisible.  A 2-device variant runs the same oracle on a
data=2 mesh under the CI multi-device job (`XLA_FLAGS=
--xla_force_host_platform_device_count=2`), where the flat-resident param
buffers actually REST as their 1/J shard.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.core.schedule import BatchPlan
from repro.data.pipeline import MarkovTokens, make_batch
from repro.distributed.train_step import (
    make_fsdp_norm_step, make_accum_norm_step)
from repro.launch.mesh import make_host_mesh, num_workers
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, init_adamw, init_adamw_flat

STEPS = 5
METRIC_KEYS = ("loss", "var_l1", "grad_sqnorm", "clip_scale")
COMBOS = [(s, p) for s in ("tree", "flat") for p in ("tree", "flat")]


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _run(step_impl: str, stats_impl: str, params_impl: str, data: int = 1):
    """5 deterministic steps; returns (per-step metric dicts, final param
    tree) — flat-resident runs convert back to the pytree view at the end."""
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=data, model=1)
    J = num_workers(mesh)
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    plan = BatchPlan(global_batch=4 * J, micro_batch=2, accum_steps=2,
                     workers=J)
    make = (make_fsdp_norm_step if step_impl == "fsdp_norm"
            else make_accum_norm_step)
    params = model.init(jax.random.PRNGKey(0))
    wrap, _, _ = make(model, AdamWConfig(), mesh, stats_impl=stats_impl,
                      params_impl=params_impl, params_like=params)
    layout = wrap.flat_layout
    opt = (init_adamw_flat(params, shard_divisor=J, layout=layout)
           if stats_impl == "flat" else init_adamw(params))
    if params_impl == "flat":
        params = tuple(layout.flatten(params))
    batches = [jax.tree.map(jnp.asarray, make_batch(src, t, plan, 16))
               for t in range(STEPS)]
    traj = []
    with set_mesh(mesh):
        fn = wrap(_sds(batches[0]))
        for t in range(STEPS):
            params, opt, m = fn(params, opt, batches[t], jnp.float32(1e-3))
            traj.append({k: float(m[k]) for k in METRIC_KEYS})
    final = (layout.unflatten(list(params)) if params_impl == "flat"
             else params)
    return traj, final


def _assert_matches_oracle(oracle, candidate, tag: str):
    o_traj, o_final = oracle
    c_traj, c_final = candidate
    for t, (o, c) in enumerate(zip(o_traj, c_traj)):
        for k in METRIC_KEYS:
            np.testing.assert_allclose(
                o[k], c[k], rtol=1e-5, atol=1e-7,
                err_msg=f"{tag}: step {t} metric {k}")
    for a, b in zip(jax.tree.leaves(o_final), jax.tree.leaves(c_final)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6, err_msg=f"{tag}: final params")


@pytest.mark.parametrize("step_impl", ["fsdp_norm", "accum_norm"])
def test_differential_oracle_all_residency_combos(step_impl):
    """Acceptance: all {stats_impl}×{params_impl} combinations match the
    tree/tree oracle to ≤1e-5 over 5 steps (loss, var_l1, grad_sqnorm,
    clip_scale, and final params)."""
    oracle = _run(step_impl, "tree", "tree")
    for stats_impl, params_impl in COMBOS[1:]:
        cand = _run(step_impl, stats_impl, params_impl)
        _assert_matches_oracle(
            oracle, cand, f"{step_impl}/{stats_impl}/{params_impl}")


@pytest.mark.parametrize("step_impl", ["fsdp_norm", "accum_norm"])
def test_traced_steps_match_frozen_layout_catalog(step_impl):
    """The equivalence matrix above proves the residency combos compute the
    same numbers; this proves they compute them with the FROZEN layout-op
    budget — the traced jaxpr of every combo carries exactly the
    pack/unflatten/adjoint eqn counts in
    `repro.analysis.EXPECTED_LAYOUT_COUNTS` (trace-only, nothing executes;
    this replaces the old `count_packs()` proxy assertions)."""
    from repro.analysis import run_invariant_checks
    combos = [(step_impl, s, p) for s in ("tree", "flat")
              for p in ("tree", "flat")]
    findings, checked = run_invariant_checks(combos=combos)
    active = [f for f in findings if not f.waived]
    assert not active, "\n".join(f.render() for f in active)
    assert len(checked["variants"]) == 4


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (CI multi-device job)")
@pytest.mark.parametrize("step_impl", ["fsdp_norm", "accum_norm"])
def test_differential_oracle_two_device(step_impl):
    """The same oracle on a data=2 mesh: the flat-resident param buffers
    rest as real 1/J shards, the FSDP-Norm manual region all-gathers them,
    and every residency combination still matches tree/tree."""
    oracle = _run(step_impl, "tree", "tree", data=2)
    for stats_impl, params_impl in COMBOS[1:]:
        cand = _run(step_impl, stats_impl, params_impl, data=2)
        _assert_matches_oracle(
            oracle, cand, f"2dev/{step_impl}/{stats_impl}/{params_impl}")


def test_flat_resident_param_specs_two_device(subproc):
    """Flat-resident param-buffer PartitionSpecs on a 2-device data mesh:
    both builders return per-bucket `P(('data',))` param specs, the live
    updated buffers actually carry the sharding (FSDP-Norm params REST as
    the 1/J shard — per-device param bytes halve), and a flat/flat step
    matches tree/tree on the same mesh."""
    out = subproc("""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.mesh import make_host_mesh
from repro.distributed.train_step import (
    make_fsdp_norm_step, make_accum_norm_step)
from repro.optim.adamw import AdamWConfig, init_adamw, init_adamw_flat
from repro.data.pipeline import MarkovTokens, make_batch
from repro.core.schedule import BatchPlan

cfg = get_smoke_config("llama3.2-1b")
model = build_model(cfg)
mesh = make_host_mesh(data=2, model=1)
src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
plan = BatchPlan(global_batch=8, micro_batch=2, accum_steps=2, workers=2)
batch = jax.tree.map(jnp.asarray, make_batch(src, 0, plan, 16))
sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
for make in (make_fsdp_norm_step, make_accum_norm_step):
    ref = None
    for stats_impl, params_impl in (("tree", "tree"), ("flat", "flat")):
        params = model.init(jax.random.PRNGKey(0))
        wrap, p_specs, _ = make(model, AdamWConfig(), mesh,
                                stats_impl=stats_impl,
                                params_impl=params_impl, params_like=params)
        layout = wrap.flat_layout
        if params_impl == "flat":
            assert len(p_specs) == layout.num_buffers
            for spec in p_specs:
                assert spec != P(), f"replicated param-buffer spec: {spec}"
                first = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
                assert "data" in first, spec
            opt = init_adamw_flat(params, shard_divisor=2, layout=layout)
            params = tuple(layout.flatten(params))
        else:
            opt = init_adamw(params)
        with set_mesh(mesh):
            p, o, m = wrap(sds)(params, opt, batch, jnp.float32(1e-3))
        if params_impl == "flat":
            total = local = 0
            for buf in p:
                assert buf.size % 2 == 0, buf.size     # J-divisible buckets
                spec0 = buf.sharding.spec[0] if buf.sharding.spec else None
                if make is make_fsdp_norm_step:
                    assert spec0 is not None, f"unsharded buffer: {buf.sharding}"
                total += buf.size
                local += buf.addressable_shards[0].data.size
            if make is make_fsdp_norm_step:
                assert local * 2 == total, (local, total)  # params rest at 1/J
            p = layout.unflatten(list(p))
        if ref is None:
            ref = (p, m)
        else:
            for k in ("loss", "var_l1", "grad_sqnorm", "clip_scale"):
                np.testing.assert_allclose(float(ref[1][k]), float(m[k]),
                                           rtol=1e-5, atol=1e-7, err_msg=k)
            for a, b in zip(jax.tree.leaves(ref[0]), jax.tree.leaves(p)):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           rtol=1e-5, atol=1e-6)
print("FLAT_RESIDENT_2DEV_OK")
""", devices=2)
    assert "FLAT_RESIDENT_2DEV_OK" in out


# ------------------------------------------ accum-free schedule oracle ----

@pytest.mark.parametrize("step_impl", ["fsdp_norm", "accum_norm"])
def test_accum_free_fixed_params_loss_equivalence(step_impl):
    """DESIGN §14 equivalence claim (A): from identical params, one
    accumulated (M=2) step's reported loss equals the valid-token-weighted
    mean of its two M=1 sub-step losses to ≤1e-5 — the sub-steps are exact
    slices of the same batch along the accumulation axis, so the re-plan
    consumes precisely the same samples."""
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    J = num_workers(mesh)
    src = MarkovTokens(vocab_size=cfg.vocab_size, seed=0)
    plan = BatchPlan(global_batch=4 * J, micro_batch=2, accum_steps=2,
                     workers=J)
    make = (make_fsdp_norm_step if step_impl == "fsdp_norm"
            else make_accum_norm_step)
    batch = jax.tree.map(jnp.asarray, make_batch(src, 0, plan, 16))
    subs = [{k: v[m:m + 1] for k, v in batch.items()} for m in range(2)]
    params0 = model.init(jax.random.PRNGKey(0))
    wrap, _, _ = make(model, AdamWConfig(), mesh, params_like=params0)
    with set_mesh(mesh):
        fn_big = wrap(_sds(batch))
        # params/opt are donated: rebuild fresh (deterministic) copies per call
        _, _, m_big = fn_big(model.init(jax.random.PRNGKey(0)),
                             init_adamw(params0), batch, jnp.float32(1e-3))
        fn_sub = wrap(_sds(subs[0]))
        losses, weights = [], []
        for sb in subs:
            _, _, m = fn_sub(model.init(jax.random.PRNGKey(0)),
                             init_adamw(params0), sb, jnp.float32(1e-3))
            losses.append(float(m["loss"]))
            weights.append(int((np.asarray(sb["labels"]) >= 0).sum()))
    want = float(np.average(losses, weights=weights))
    np.testing.assert_allclose(float(m_big["loss"]), want, rtol=1e-5,
                               atol=1e-7)


def test_accum_free_end_to_end_same_samples_loose_loss():
    """DESIGN §14 equivalence claim (B)+(C): a run with accum_free re-plans
    its low rungs as M=1 × more optimizer steps, consumes EXACTLY the same
    per-scheduled-step samples as the accumulated run, and lands within a
    loose loss tolerance of it (the trajectories are different optimizers —
    M small steps vs one accumulated step — so only (A) is a ≤1e-5 claim)."""
    from repro.launch.train import TrainJob, run_training
    kw = dict(arch="llama3.2-1b", schedule="constant", step_impl="accum_norm",
              steps=6, seq_len=32, base_global_batch=8, max_global_batch=8,
              base_micro_batch=2, max_micro_batch=2, base_accum=2,
              eval_every=0)
    off = run_training(TrainJob(**kw))
    on = run_training(TrainJob(**kw, accum_free=True, accum_free_below=64))
    # (B) exact same-samples accounting, step by step
    assert on["samples"] == off["samples"]
    assert on["global_batch"] == off["global_batch"]
    # the re-plan actually happened: M=1 executed, M optimizer steps
    assert set(on["accum_steps"]) == {1}
    assert set(on["opt_steps"]) == {4}
    assert set(off["accum_steps"]) == {4}
    assert set(off["opt_steps"]) == {1}
    # (C) loose end-to-end loss agreement
    np.testing.assert_allclose(on["loss"], off["loss"], rtol=0.1, atol=0.05)


def test_params_impl_validation():
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    with pytest.raises(ValueError):
        make_fsdp_norm_step(model, AdamWConfig(), mesh, params_impl="bogus")
    with pytest.raises(ValueError):
        make_fsdp_norm_step(model, AdamWConfig(), mesh, params_impl="flat",
                            variance_impl="paper")
    with pytest.raises(ValueError):
        make_accum_norm_step(model, AdamWConfig(), mesh, params_impl="nope")
