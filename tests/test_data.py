"""Data pipeline: determinism, dynamic re-sharding, learnable structure."""
import numpy as np

from repro.core.schedule import BatchPlan
from repro.data.pipeline import (
    MarkovTokens, UniformTokens, MemmapTokens, make_batch, microbatches)


def test_deterministic():
    src = MarkovTokens(vocab_size=64, seed=3)
    a = src.sequences(5, 4, 16)
    b = src.sequences(5, 4, 16)
    assert (a == b).all()
    c = src.sequences(6, 4, 16)
    assert not (a == c).all()


def test_markov_structure_learnable():
    src = MarkovTokens(vocab_size=64, fan_out=4, seed=0)
    seqs = src.sequences(0, 8, 100)
    # every transition must be in the chain's successor table
    for row in seqs:
        for t in range(len(row) - 1):
            assert row[t + 1] in src._succ[row[t]]


def test_batch_layout_follows_plan():
    src = UniformTokens(vocab_size=100, seed=0)
    plan = BatchPlan(global_batch=24, micro_batch=3, accum_steps=2, workers=4)
    b = make_batch(src, 0, plan, seq_len=8)
    assert b["tokens"].shape == (2, 12, 8)
    assert b["labels"].shape == (2, 12, 8)
    # next-token alignment
    seqs = src.sequences(0, 24, 8)
    assert (b["tokens"][0, 0] == seqs[0, :-1]).all()
    assert (b["labels"][0, 0] == seqs[0, 1:]).all()
    # dynamic re-shard: new plan, same source
    plan2 = BatchPlan(global_batch=48, micro_batch=6, accum_steps=2, workers=4)
    b2 = make_batch(src, 1, plan2, seq_len=8)
    assert b2["tokens"].shape == (2, 24, 8)


def test_microbatch_iterator():
    src = UniformTokens(vocab_size=10, seed=0)
    plan = BatchPlan(global_batch=8, micro_batch=2, accum_steps=2, workers=2)
    b = make_batch(src, 0, plan, seq_len=4)
    micros = list(microbatches(b))
    assert len(micros) == 2
    assert micros[0]["tokens"].shape == (4, 4)


def test_extra_inputs_independent_of_hash_randomization(subproc):
    """Bugfix regression: `make_batch` seeded extra frontend inputs with
    `hash(name)`, which PYTHONHASHSEED randomizes PER PROCESS — different
    hosts materialized different vision/audio batches, silently violating
    the "pure function of (seed, step, plan)" multi-host contract.  Two
    processes with different hash seeds must produce identical batches."""
    code = """
import zlib
import numpy as np
from repro.core.schedule import BatchPlan
from repro.data.pipeline import UniformTokens, make_batch
src = UniformTokens(vocab_size=32, seed=0)
plan = BatchPlan(global_batch=4, micro_batch=2, accum_steps=2, workers=1)
b = make_batch(src, 3, plan, 8,
               {"patch_embeds": (4, 8), "frames": (2, 8)})
digest = zlib.crc32(b"".join(np.ascontiguousarray(v).tobytes()
                             for _, v in sorted(b.items())))
print("DIGEST", digest)
"""
    outs = {subproc(code, env_extra={"PYTHONHASHSEED": hs}).strip()
            for hs in ("0", "424242")}
    assert len(outs) == 1, f"extra inputs depend on hash seed: {outs}"


def test_memmap_too_short_raises_clear_error(tmp_path):
    """Bugfix regression: a corpus shorter than seq_len + 2 used to crash
    deep inside `rng.integers` (`high <= 0`); it must raise a clear error
    naming the corpus, its size, and the requirement."""
    import pytest

    path = tmp_path / "short.bin"
    np.arange(10, dtype=np.int32).tofile(path)
    src = MemmapTokens(str(path), vocab_size=50, seed=0)
    with pytest.raises(ValueError, match="too short.*seq_len=16"):
        src.sequences(0, 2, seq_len=16)
    # boundary: seq_len + 2 tokens is exactly enough (one valid start)
    path2 = tmp_path / "exact.bin"
    np.arange(18, dtype=np.int32).tofile(path2)
    seqs = MemmapTokens(str(path2), vocab_size=50, seed=0).sequences(0, 2, 16)
    assert seqs.shape == (2, 17)
    # an empty corpus fails at construction, not first sample
    path3 = tmp_path / "empty.bin"
    path3.touch()
    with pytest.raises(ValueError, match="empty"):
        MemmapTokens(str(path3), vocab_size=50, seed=0)


def test_memmap_source(tmp_path):
    data = np.arange(1000, dtype=np.int32) % 50
    path = tmp_path / "tokens.bin"
    data.tofile(path)
    src = MemmapTokens(str(path), vocab_size=50, seed=0)
    seqs = src.sequences(0, 3, 16)
    assert seqs.shape == (3, 17)
    assert seqs.max() < 50
    # contiguity: consecutive tokens differ by 1 mod 50
    d = (seqs[:, 1:] - seqs[:, :-1]) % 50
    assert (d == 1).all()
