"""Data pipeline: determinism, dynamic re-sharding, learnable structure."""
import numpy as np

from repro.core.schedule import BatchPlan
from repro.data.pipeline import (
    MarkovTokens, UniformTokens, MemmapTokens, make_batch, microbatches)


def test_deterministic():
    src = MarkovTokens(vocab_size=64, seed=3)
    a = src.sequences(5, 4, 16)
    b = src.sequences(5, 4, 16)
    assert (a == b).all()
    c = src.sequences(6, 4, 16)
    assert not (a == c).all()


def test_markov_structure_learnable():
    src = MarkovTokens(vocab_size=64, fan_out=4, seed=0)
    seqs = src.sequences(0, 8, 100)
    # every transition must be in the chain's successor table
    for row in seqs:
        for t in range(len(row) - 1):
            assert row[t + 1] in src._succ[row[t]]


def test_batch_layout_follows_plan():
    src = UniformTokens(vocab_size=100, seed=0)
    plan = BatchPlan(global_batch=24, micro_batch=3, accum_steps=2, workers=4)
    b = make_batch(src, 0, plan, seq_len=8)
    assert b["tokens"].shape == (2, 12, 8)
    assert b["labels"].shape == (2, 12, 8)
    # next-token alignment
    seqs = src.sequences(0, 24, 8)
    assert (b["tokens"][0, 0] == seqs[0, :-1]).all()
    assert (b["labels"][0, 0] == seqs[0, 1:]).all()
    # dynamic re-shard: new plan, same source
    plan2 = BatchPlan(global_batch=48, micro_batch=6, accum_steps=2, workers=4)
    b2 = make_batch(src, 1, plan2, seq_len=8)
    assert b2["tokens"].shape == (2, 24, 8)


def test_microbatch_iterator():
    src = UniformTokens(vocab_size=10, seed=0)
    plan = BatchPlan(global_batch=8, micro_batch=2, accum_steps=2, workers=2)
    b = make_batch(src, 0, plan, seq_len=4)
    micros = list(microbatches(b))
    assert len(micros) == 2
    assert micros[0]["tokens"].shape == (4, 4)


def test_memmap_source(tmp_path):
    data = np.arange(1000, dtype=np.int32) % 50
    path = tmp_path / "tokens.bin"
    data.tofile(path)
    src = MemmapTokens(str(path), vocab_size=50, seed=0)
    seqs = src.sequences(0, 3, 16)
    assert seqs.shape == (3, 17)
    assert seqs.max() < 50
    # contiguity: consecutive tokens differ by 1 mod 50
    d = (seqs[:, 1:] - seqs[:, :-1]) % 50
    assert (d == 1).all()
