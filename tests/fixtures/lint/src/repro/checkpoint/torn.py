"""Fixture: nonatomic-write violations (torn checkpoint class, DESIGN §12)."""

import os


def rename_commit(tmp, final):
    os.rename(tmp, final)  # VIOLATION nonatomic-write (os.rename)


def in_place_write(path, payload):
    with open(path, "w") as f:  # VIOLATION nonatomic-write (in-place)
        f.write(payload)


def atomic_write(path, payload):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:  # clean: fsync + os.replace in this function
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
