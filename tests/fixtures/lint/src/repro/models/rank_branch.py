"""Fixture: host-divergence violations (per-rank values in traced scope)."""

import os

import jax


def rank_dependent_depth(x):
    if jax.process_index() == 0:  # VIOLATION host-divergence
        return x * 2
    return x


def pid_seeded(x):
    return x + os.getpid()  # VIOLATION host-divergence


def waived_rank_read():
    return jax.process_count()  # repro: allow(host-divergence) — fixture
