"""Fixture: set-iter-order violations (PYTHONHASHSEED-dependent order)."""


def loop_over_literal(out):
    for name in {"wq", "wk", "wv"}:  # VIOLATION set-iter-order
        out.append(name)


def comp_over_call(names):
    return [n.upper() for n in set(names)]  # VIOLATION set-iter-order


def sorted_is_clean(names):
    return [n for n in sorted(set(names))]  # clean
