"""Fixture: hash-seed violations (the PR 5 per-host desync class)."""


def seeded_key(name):
    # a hash()-seeded cache key is PYTHONHASHSEED-randomized per process,
    # so hosts disagree on which entry they share
    return hash(name) % 1024  # VIOLATION hash-seed


def object_key(obj):
    return id(obj)  # VIOLATION hash-seed


def waived_key(name):
    # repro: allow(hash-seed) — fixture exercising waiver suppression
    return hash(name)  # WAIVED hash-seed


class Wrapped:
    def __init__(self, inner):
        self.inner = inner

    def __hash__(self):
        # exempt: delegating to hash() inside __hash__ IS the protocol
        return hash(self.inner)
