"""Fixture: bare-interpret violation (Pallas pinned to host interpret)."""


def launch(kernel, x):
    return kernel(x, interpret=True)  # VIOLATION bare-interpret


def routed(kernel, x, resolve_interpret):
    return kernel(x, interpret=resolve_interpret(None))  # clean
