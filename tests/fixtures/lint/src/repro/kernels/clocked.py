"""Fixture: wallclock-traced violations (clock reads in traced-code scope)."""

import time


def traced_span(x):
    t0 = time.monotonic()  # VIOLATION wallclock-traced
    return x * 2, t0


def waived_span(x):
    t0 = time.perf_counter()  # repro: allow(wallclock-traced) — fixture
    return x * 2, t0
