"""Fixture: unfenced-timing violation (the PR 6 dispatch-timing leak)."""

import time


def leaky_span(step, args):
    t0 = time.perf_counter()  # VIOLATION unfenced-timing (first read)
    out = step(*args)
    t1 = time.perf_counter()
    return out, t1 - t0


def fenced_span(step, args, jax):
    t0 = time.perf_counter()
    out = jax.block_until_ready(step(*args))
    t1 = time.perf_counter()
    return out, t1 - t0


def single_read_timestamp():
    return time.time()  # clean: one read is a timestamp, not a span
