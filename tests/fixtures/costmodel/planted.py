"""Planted cost/divergence regressions for the layer-3 analyzer tests.

Each pair here is (clean step, regressed twin) for one class the cost
model gates:

* `clean_step` / `extra_gather_step` — the twin performs one additional
  `all_gather` whose result feeds the output: a collective-volume
  regression (new kind, new bytes) the budget diff must flag exactly.
* `donating_update` / `dropped_donation_update` — the same params update
  with and without `donate_argnums`: the dropped donation doubles the
  resident params state, which the peak-memory watermark must price in.
* `make_flipping_step` — a builder whose collective EMISSION ORDER
  depends on mutable host state (a per-call counter standing in for
  `process_index()`): two traces of the same fn produce different
  ordered signatures, the divergence-order deadlock class.
* `cond_collective_step` — a `lax.cond` with a psum in only one branch:
  ranks whose predicate differs disagree on the next collective
  (divergence-cond).

All functions are trace-only fixtures — nothing here is ever compiled or
executed; meshes are host meshes over however many devices the test
process has (collectives emit at trace time even on size-1 axes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map


def fixture_mesh():
    """One manual data axis over every local device."""
    return make_mesh((jax.device_count(),), ("d",))


def _sharded(body, mesh):
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P("d"),
                             out_specs=P("d"), axis_names={"d"},
                             check_vma=False))


def clean_step(mesh):
    """Baseline: exactly one psum over the data axis."""
    def body(x):
        local = jnp.sum(x * x)
        total = lax.psum(local, "d")
        return x * total
    return _sharded(body, mesh)


def extra_gather_step(mesh):
    """The planted regression: same math plus one all_gather whose result
    feeds the output (so DCE cannot delete it)."""
    def body(x):
        local = jnp.sum(x * x)
        total = lax.psum(local, "d")
        gathered = lax.all_gather(x, "d")
        return x * total + jnp.sum(gathered)
    return _sharded(body, mesh)


def _update(params, grad):
    new_params = params - 0.1 * grad
    return new_params, jnp.sum(grad * grad)


def donating_update(n: int = 1 << 18):
    """(jitted fn, example args): params buffer donated, so XLA aliases it
    to the output and the update runs in place."""
    x = jnp.zeros((n,), jnp.float32)
    return jax.jit(_update, donate_argnums=(0,)), (x, x)


def dropped_donation_update(n: int = 1 << 18):
    """The planted regression: the identical update WITHOUT the donation —
    old and new params are simultaneously resident."""
    x = jnp.zeros((n,), jnp.float32)
    return jax.jit(_update), (x, x)


def make_flipping_step(mesh):
    """A builder with host-state-dependent emission order: odd calls emit
    psum-then-all_gather, even calls the reverse.  The mutable counter is
    the single-process stand-in for branching on `jax.process_index()` —
    two ranks (or two traces) build different programs."""
    calls = {"n": 0}

    def body(x):
        calls["n"] += 1
        if calls["n"] % 2:
            total = lax.psum(jnp.sum(x), "d")
            gathered = lax.all_gather(x, "d")
        else:
            gathered = lax.all_gather(x, "d")
            total = lax.psum(jnp.sum(x), "d")
        return x * total + jnp.sum(gathered)

    return _sharded(body, mesh)


def cond_collective_step(mesh):
    """A data-dependent branch where only the true arm psums: ranks whose
    predicate disagrees deadlock at the collective."""
    def body(x):
        def with_psum(v):
            return v * lax.psum(jnp.sum(v), "d")

        def without(v):
            return v * 2.0

        return lax.cond(jnp.sum(x) > 0, with_psum, without, x)
    return _sharded(body, mesh)
