"""Multi-host warmup coordination (DESIGN §8.1): file-backed barriers /
agreement / failure broadcast, the engine's coordinated-rung-entry behavior,
the 2-process coordinated-warmup acceptance bar, and persistent compile-cache
reuse across an engine restart."""
import json
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.core.schedule import parse_ladder
from repro.data.pipeline import MarkovTokens, make_batch
from repro.distributed import engine as engine_mod
from repro.distributed.coordination import (
    FileCoordinator, NoOpCoordinator, make_coordinator)
from repro.distributed.engine import BucketedEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


# ------------------------------------------------------ file coordinator ----

def _pair(tmp_path, **kw):
    d = str(tmp_path / "coord")
    return (FileCoordinator(d, 0, 2, **kw), FileCoordinator(d, 1, 2, **kw))


def test_barrier_meets_and_reports_wait(tmp_path):
    c0, c1 = _pair(tmp_path)
    waits = {}

    def late():
        time.sleep(0.15)
        waits[1] = c1.barrier("entry")

    t = threading.Thread(target=late)
    t.start()
    waits[0] = c0.barrier("entry")     # must wait ~0.15s for rank 1
    t.join()
    assert waits[0] >= 0.1             # the early host measured real waiting
    assert waits[1] < 5.0


def test_barrier_generations_allow_reentry(tmp_path):
    """The same barrier NAME crossed twice (rung re-entry after an
    oscillating controller) gets a fresh generation — the second crossing
    really synchronizes instead of sailing through stale rank files."""
    c0, c1 = _pair(tmp_path)
    for _ in range(2):
        t = threading.Thread(target=lambda: c1.barrier("rung-abc"))
        t.start()
        c0.barrier("rung-abc")
        t.join()
    # generation 2 was a real rendezvous: rank 1 alone at a THIRD crossing
    # times out instead of finding leftover files
    with pytest.raises(TimeoutError, match="1/2"):
        c1.barrier("rung-abc", timeout=0.2)


def test_barrier_timeout_names_the_missing_fleet(tmp_path):
    c0, _ = _pair(tmp_path, timeout=0.25)
    with pytest.raises(TimeoutError) as ei:
        c0.barrier("rung-dead")
    msg = str(ei.value)
    assert "rung-dead" in msg and "1/2" in msg


def test_agreement_leader_wins_and_is_write_once(tmp_path):
    c0, c1 = _pair(tmp_path)
    got = {}
    t = threading.Thread(target=lambda: got.update(f=c1.agree("warmup-1", "8x2")))
    t.start()
    got["l"] = c0.agree("warmup-1", "4x2")
    t.join()
    assert got == {"l": "4x2", "f": "4x2"}     # follower adopted the leader
    # a restarted leader re-publishing the topic must NOT clobber the
    # decision followers already consumed
    assert c0.agree("warmup-1", "16x1") == "4x2"


def test_agreement_follower_timeout(tmp_path):
    _, c1 = _pair(tmp_path, timeout=0.25)
    with pytest.raises(TimeoutError, match="warmup-9"):
        c1.agree("warmup-9", "4x2")


def test_failure_broadcast_is_fleet_visible_and_idempotent(tmp_path):
    c0, c1 = _pair(tmp_path)
    assert c1.poll_failures() == frozenset()
    c0.broadcast_failure("deadbeef")
    c0.broadcast_failure("deadbeef")           # idempotent re-broadcast
    assert c1.poll_failures() == frozenset({"deadbeef"})
    c1.broadcast_failure("cafe0001")
    assert c0.poll_failures() == frozenset({"deadbeef", "cafe0001"})


def test_noop_coordinator_is_free():
    c = NoOpCoordinator()
    assert c.barrier("x") == 0.0
    assert c.agree("t", "4x2") == "4x2"
    c.broadcast_failure("x")
    assert c.poll_failures() == frozenset()


def test_distributed_coordinator_world_of_one():
    """The jax.distributed-backed impl degenerates correctly on a single
    process: free barriers (the allgather spans one host), echo agreement,
    and the barrier's failure exchange keeps local failures visible."""
    c = make_coordinator("distributed")
    assert (c.rank, c.world) == (0, 1)
    assert c.barrier("rung-x") >= 0.0
    assert c.agree("t1", "4x2") == "4x2"
    c.broadcast_failure("aabbccdd")
    assert "aabbccdd" in c.poll_failures()
    c.barrier("rung-y")                    # failure exchange round-trips
    assert "aabbccdd" in c.poll_failures()


def test_make_coordinator_resolution(tmp_path, monkeypatch):
    assert make_coordinator("none") is None
    with pytest.raises(ValueError, match="coord-dir"):
        make_coordinator("file")
    with pytest.raises(ValueError, match="unknown"):
        make_coordinator("gossip", root=str(tmp_path))
    monkeypatch.setenv("REPRO_COORD_RANK", "1")
    monkeypatch.setenv("REPRO_COORD_WORLD", "3")
    c = make_coordinator("file", root=str(tmp_path / "c"))
    assert (c.rank, c.world) == (1, 3)
    explicit = make_coordinator("file", root=str(tmp_path / "c"), rank=0,
                                world=2)
    assert (explicit.rank, explicit.world) == (0, 2)
    # run_id namespaces the shared dir: a different job reusing the same
    # --coord-dir cannot replay this run's barriers/agreements
    a = make_coordinator("file", root=str(tmp_path / "c"), rank=0, world=1,
                         run_id="job-aaaa")
    b = make_coordinator("file", root=str(tmp_path / "c"), rank=0, world=1,
                         run_id="job-bbbb")
    assert a.root != b.root
    a.broadcast_failure("dead")
    assert b.poll_failures() == frozenset()     # isolated namespaces
    with pytest.raises(ValueError, match="geometry"):
        FileCoordinator(str(tmp_path / "c"), rank=5, world=2)


# ------------------------------------------- engine coordination hooks ----

def test_remote_failure_downgrades_queued_warmup(tmp_path):
    """A rung another host flagged as warmup-failed gets its queued-not-
    started local warmup cancelled at rung entry (the coherent synchronous
    downgrade), counted in `coord_downgrades`, and the step is built in the
    foreground — no warmup_failure is charged to THIS host."""
    coord_a = FileCoordinator(str(tmp_path / "c"), 0, 2)
    coord_b = FileCoordinator(str(tmp_path / "c"), 1, 2)
    ladder = parse_ladder("2:1,2:2,2:4", workers=1)
    gate = threading.Event()

    class FakeJitted:
        def __init__(self, block):
            self.block = block

        def lower(self, *a):
            if self.block:
                gate.wait(timeout=30)
            return self

        def compile(self):
            return lambda *a: None

    built = []

    def wrap(batch_like):
        shapes = tuple(v.shape for v in batch_like.values())
        built.append(shapes)
        # the FIRST background build (rung 2:2) blocks the one-worker pool
        # so the 2:4 warmup stays QUEUED
        return FakeJitted(block=len(built) == 1)

    eng = BucketedEngine(wrap, ladder, params_like={}, opt_like={},
                         aot_warmup=True, coordinator=coord_b)
    src = MarkovTokens(vocab_size=32, seed=0)
    batch0 = make_batch(src, 0, ladder[0], seq_len=4)
    eng.warmup(ladder[1], batch0)      # running (blocked on gate)
    eng.warmup(ladder[2], batch0)      # queued behind it
    batch2 = make_batch(src, 1, ladder[2], seq_len=4)
    tag = engine_mod._key_tag(engine_mod._batch_key(batch2))
    # host A's warmup of the 2:4 rung died and was broadcast
    coord_a.broadcast_failure(tag)
    t = threading.Thread(target=lambda: coord_a.barrier(f"rung-{tag}"))
    t.start()
    fn = eng.get_step(batch2)          # downgrade + barrier + foreground build
    t.join()
    assert fn is not None
    assert eng.stats.coord_downgrades == 1
    assert eng.stats.warmup_failures == 0      # the failure was REMOTE
    assert eng.stats.barriers == 1
    gate.set()
    eng.drain()                        # the blocked 2:2 warmup completes fine
    assert eng.stats.warmups == 1


def test_engine_broadcasts_own_warmup_failure_promptly(tmp_path):
    """A failing background compile broadcasts its rung tag BEFORE any local
    consumption of the future — other hosts can downgrade while this host is
    still mid-step."""
    coord = FileCoordinator(str(tmp_path / "c"), 0, 2)
    observer = FileCoordinator(str(tmp_path / "c"), 1, 2)
    ladder = parse_ladder("2:1,2:2", workers=1)

    class Exploding:
        def lower(self, *a):
            raise RuntimeError("boom")

    eng = BucketedEngine(lambda bl: Exploding(), ladder, params_like={},
                         opt_like={}, aot_warmup=True, coordinator=coord)
    src = MarkovTokens(vocab_size=32, seed=0)
    eng.warmup(ladder[1], make_batch(src, 0, ladder[0], seq_len=4))
    deadline = time.monotonic() + 10
    while not observer.poll_failures():
        assert time.monotonic() < deadline, "failure never broadcast"
        time.sleep(0.01)
    # local accounting still happens exactly once, at consumption
    assert eng.stats.warmup_failures == 0
    with pytest.raises(RuntimeError, match="warmup compile"):
        eng.drain()
    assert eng.stats.warmup_failures == 1


# ------------------------------------- 2-process acceptance + restarts ----

_TWO_PROC_ENGINE = """
import json, sys
import jax, jax.numpy as jnp
from repro.core.schedule import parse_ladder
from repro.data.pipeline import MarkovTokens, make_batch
from repro.distributed.coordination import FileCoordinator
from repro.distributed.engine import BucketedEngine

rank = int(sys.argv[1])
coord = FileCoordinator(sys.argv[2], rank, 2, timeout=90.0)

def wrap(batch_like):
    return jax.jit(lambda p, o, b, lr: (p, o, {"loss": sum(
        jnp.sum(v) for v in b.values())}))

ladder = parse_ladder("2:1,2:2", workers=1)
eng = BucketedEngine(wrap, ladder, params_like={}, opt_like={},
                     aot_warmup=True, coordinator=coord)
src = MarkovTokens(vocab_size=32, seed=0)
batch0 = make_batch(src, 0, ladder[0], seq_len=8)
fn0 = eng.get_step(batch0)                     # rung-entry barrier + compile
eng.observe(ladder[0], ladder[0])
agreed = eng.warmup_agreed(ladder[0], batch0)  # fleet agrees: warm 2:2
assert agreed == ladder[1], agreed
eng.drain()                                    # background compile lands
before = (eng.stats.hits, eng.stats.compiles)
batch1 = make_batch(src, 1, ladder[1], seq_len=8)
fn1 = eng.get_step(batch1)                     # the post-increase step
after = (eng.stats.hits, eng.stats.compiles)
print("STATS", json.dumps({"rank": rank, "before": before, "after": after,
                           "engine": eng.stats.as_dict()}))
"""


def _launch_ranks(code, args, n=2, timeout=180):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    procs = [subprocess.Popen([sys.executable, "-c", code, str(r), *args],
                              stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                              text=True, env=env) for r in range(n)]
    outs = []
    for p in procs:
        out, err = p.communicate(timeout=timeout)
        assert p.returncode == 0, f"rank failed:\n{out}\n{err}"
        outs.append(out)
    return outs


def test_two_process_coordinated_warmup_post_increase_is_cache_hit(tmp_path):
    """The acceptance bar: after a coordinated warmup of the next rung, the
    first post-increase step is a cache hit on BOTH processes — `hits` goes
    up, `compiles` does not — with zero desyncs and two rung-entry barriers
    crossed by each host."""
    outs = _launch_ranks(_TWO_PROC_ENGINE, [str(tmp_path / "coord")])
    for out in outs:
        line = next(l for l in out.splitlines() if l.startswith("STATS"))
        s = json.loads(line.split(" ", 1)[1])
        hits0, compiles0 = s["before"]
        hits1, compiles1 = s["after"]
        assert hits1 == hits0 + 1, s          # post-increase step: a hit...
        assert compiles1 == compiles0, s      # ...not a foreground compile
        eng = s["engine"]
        assert eng["warmups"] == 1 and eng["warmup_failures"] == 0
        assert eng["desyncs"] == 0
        assert eng["barriers"] == 2           # one entry per distinct rung
        assert eng["compiles"] == 2           # first rung + the AOT warmup


_TWO_PROC_TRAIN = """
import json, sys
from repro.launch.train import TrainJob, run_training
rank, coord_dir = int(sys.argv[1]), sys.argv[2]
job = TrainJob(arch="llama3.2-1b", schedule="stagewise",
               stages=((0.5, 4), (0.5, 8)), steps=12, total_samples=48,
               seq_len=16, base_global_batch=4, max_global_batch=8,
               base_micro_batch=2, max_micro_batch=2, base_accum=2,
               step_impl="accum_norm", eval_every=0, aot_warmup=True,
               coord="file", coord_dir=coord_dir, coord_rank=rank,
               coord_world=2, coord_timeout=120.0)
h = run_training(job)
print("HIST", json.dumps({"rank": rank, "loss": h["loss"],
                          "gb": h["global_batch"], "engine": h["engine"]}))
"""


def test_two_process_training_over_batch_increase(tmp_path):
    """End-to-end `run_training` on two file-coordinated processes across a
    stagewise 4→8 increase: zero foreground compiles after the first rung on
    BOTH hosts (every later step a hit — the warmup covered the increase),
    zero desyncs/warmup failures, and bit-identical loss histories (the
    determinism contract the crc32 seed fix protects)."""
    outs = _launch_ranks(_TWO_PROC_TRAIN, [str(tmp_path / "coord")],
                         timeout=420)
    hists = []
    for out in outs:
        line = next(l for l in out.splitlines() if l.startswith("HIST"))
        hists.append(json.loads(line.split(" ", 1)[1]))
    for h in hists:
        eng = h["engine"]
        assert max(h["gb"]) == 8 and min(h["gb"]) == 4   # increase happened
        assert eng["warmup_failures"] == 0 and eng["desyncs"] == 0
        # the ONLY foreground compile is the very first rung; the increase
        # rode the coordinated warmup on this host
        assert eng["compiles"] - eng["warmups"] == 1, eng
        assert eng["hits"] == eng["steps"] - 1, eng
        assert eng["barriers"] == 2, eng
    assert hists[0]["loss"] == hists[1]["loss"]          # bit-identical


_RESTART_CACHE = """
import json, sys
import jax, jax.numpy as jnp
from repro.core.schedule import parse_ladder
from repro.data.pipeline import MarkovTokens, make_batch
from repro.distributed.engine import BucketedEngine

def wrap(batch_like):
    return jax.jit(lambda p, o, b, lr: (p, o, {"loss": sum(
        jnp.sum(v) for v in b.values())}))

ladder = parse_ladder("2:1", workers=1)
eng = BucketedEngine(wrap, ladder, persistent_cache_dir=sys.argv[2])
src = MarkovTokens(vocab_size=32, seed=0)
batch = make_batch(src, 0, ladder[0], seq_len=8)
fn = eng.get_step(batch)
out = fn({}, {}, {k: jnp.asarray(v) for k, v in batch.items()},
         jnp.float32(0.0))                     # lazy compile happens HERE
jax.block_until_ready(out)
eng.drain()
print("STATS", json.dumps(eng.stats.as_dict()))
"""


def test_persistent_cache_reused_across_engine_restart(tmp_path):
    """A restarted worker (fresh process, same per-job cache dir) must
    deserialize the executable from disk instead of recompiling:
    `disk_cache_hits` is 0 on the cold run and positive after restart."""
    cache = str(tmp_path / "compile-cache")
    cold = _launch_ranks(_RESTART_CACHE, [cache], n=1)[0]
    warm = _launch_ranks(_RESTART_CACHE, [cache], n=1)[0]
    s_cold = json.loads(next(l for l in cold.splitlines()
                             if l.startswith("STATS")).split(" ", 1)[1])
    s_warm = json.loads(next(l for l in warm.splitlines()
                             if l.startswith("STATS")).split(" ", 1)[1])
    assert s_cold["disk_cache_hits"] == 0, s_cold
    assert s_warm["disk_cache_hits"] >= 1, s_warm
    assert s_warm["compiles"] == s_cold["compiles"] == 1   # 1 trace each run


def test_coord_none_bit_identical_to_uncoordinated(tmp_path):
    """--coord=none must be byte-for-byte the PR 4 single-host engine: same
    losses, same engine stats, and a file-coordinated world-of-one run also
    matches (its barriers are real but free)."""
    from repro.launch.train import TrainJob, run_training
    base = dict(arch="llama3.2-1b", steps=6, seq_len=16, base_global_batch=4,
                max_global_batch=16, base_micro_batch=2, max_micro_batch=2,
                base_accum=2, eta=0.12, step_impl="accum_norm", eval_every=0,
                aot_warmup=True)
    h_none = run_training(TrainJob(**base))
    h_solo = run_training(TrainJob(coord="file",
                                   coord_dir=str(tmp_path / "c"),
                                   coord_rank=0, coord_world=1, **base))
    assert h_none["loss"] == h_solo["loss"]              # bit-identical
    e_none, e_solo = h_none["engine"], h_solo["engine"]
    for k in ("compiles", "hits", "warmups", "steps", "buckets_used"):
        assert e_none[k] == e_solo[k], k
    assert e_none["barriers"] == 0                       # no coordinator
    assert e_solo["desyncs"] == e_solo["coord_downgrades"] == 0


# -------------------------------------------------- liveness (§12) ----

def test_barrier_timeout_is_typed_with_missing_ranks(tmp_path):
    """Both ranks alive (fresh heartbeats), one never arrives: a plain
    timeout, but TYPED and naming the missing rank id, not just a count."""
    from repro.distributed.coordination import CoordinationError
    c0, c1 = _pair(tmp_path, timeout=0.25)
    with pytest.raises(CoordinationError) as ei:
        c0.barrier("rung-solo")
    assert ei.value.missing_ranks == (1,)
    assert ei.value.dead_ranks == ()            # its heartbeat is fresh
    assert "missing ranks: [1]" in str(ei.value)
    assert isinstance(ei.value, TimeoutError)   # pre-liveness contract
    c0.close(), c1.close()


def test_barrier_fails_fast_when_missing_rank_is_dead(tmp_path):
    """A rank whose heartbeat was seen then went stale is DEAD: the barrier
    raises immediately with the blame attached instead of burning the full
    timeout."""
    from repro.distributed.coordination import CoordinationError
    d = str(tmp_path / "coord")
    c1 = FileCoordinator(d, 1, 2, heartbeat_s=0.05, dead_after=0.3)
    c1.close()                                  # rank 1 "dies": beat stops
    c0 = FileCoordinator(d, 0, 2, heartbeat_s=0.05, dead_after=0.3,
                         timeout=60.0)
    time.sleep(0.45)                            # let the heartbeat go stale
    t0 = time.monotonic()
    with pytest.raises(CoordinationError) as ei:
        c0.barrier("rung-x")
    assert time.monotonic() - t0 < 10.0         # fail-fast, not 60s
    assert ei.value.dead_ranks == (1,)
    assert "dead ranks (stale heartbeat): [1]" in str(ei.value)
    c0.close()


def test_agree_fails_fast_when_leader_is_dead(tmp_path):
    from repro.distributed.coordination import CoordinationError
    d = str(tmp_path / "coord")
    c0 = FileCoordinator(d, 0, 2, heartbeat_s=0.05, dead_after=0.3)
    c0.close()                                  # leader dies pre-publication
    c1 = FileCoordinator(d, 1, 2, heartbeat_s=0.05, dead_after=0.3,
                         timeout=60.0)
    time.sleep(0.45)
    t0 = time.monotonic()
    with pytest.raises(CoordinationError, match="heartbeat is stale") as ei:
        c1.agree("warmup-3", "4x2")
    assert time.monotonic() - t0 < 10.0
    assert ei.value.dead_ranks == (0,)
    c1.close()


def test_live_rank_never_reads_as_dead(tmp_path):
    """The heartbeat thread keeps a healthy rank fresh well past dead_after;
    only after it stops does the rank turn stale."""
    d = str(tmp_path / "coord")
    c0 = FileCoordinator(d, 0, 2, heartbeat_s=0.05, dead_after=0.25)
    c1 = FileCoordinator(d, 1, 2, heartbeat_s=0.05, dead_after=0.25)
    time.sleep(0.5)                    # several dead_after windows
    assert c0.dead_ranks() == frozenset()
    c1.close()
    time.sleep(0.5)
    assert c0.dead_ranks() == frozenset({1})
    # a never-seen rank is only MISSING (could still be launching), not dead
    solo = FileCoordinator(str(tmp_path / "c2"), 0, 3, heartbeat_s=0.05,
                           dead_after=0.25)
    time.sleep(0.4)
    assert solo.dead_ranks() == frozenset()
    solo.close(), c0.close()
