"""AdamW: tree update vs ref, clipping, lr schedules, fused-kernel parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (
    AdamWConfig, init_adamw, adamw_update, clip_by_global_norm,
    warmup_cosine)
from repro.kernels import ref

KEY = jax.random.PRNGKey(3)


def tree_like():
    k1, k2 = jax.random.split(KEY)
    return {"a": jax.random.normal(k1, (33,)),
            "b": {"w": jax.random.normal(k2, (8, 16))}}


def test_adamw_matches_ref():
    params = tree_like()
    grads = jax.tree.map(lambda x: x * 0.1, params)
    opt = init_adamw(params)
    cfg = AdamWConfig(grad_clip=0.0)
    new_params, new_opt, _ = adamw_update(params, grads, opt, cfg, 1e-3)
    # reference: leaf-wise adamw with c1/c2 for count=1
    for path in ("a",):
        p, g = params[path], grads[path]
        want_p, want_m, want_v = ref.adamw_ref(
            p, g, jnp.zeros_like(p), jnp.zeros_like(p), lr=1e-3,
            beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, c1=1 - cfg.beta1, c2=1 - cfg.beta2)
        np.testing.assert_allclose(new_params[path], want_p, rtol=1e-5)
        np.testing.assert_allclose(new_opt["m"][path], want_m, rtol=1e-5)


def test_fused_kernel_path_matches():
    params = tree_like()
    grads = jax.tree.map(lambda x: x * 0.3, params)
    opt1 = init_adamw(params)
    opt2 = init_adamw(params)
    p1, o1, _ = adamw_update(params, grads, opt1, AdamWConfig(), 2e-3)
    p2, o2, _ = adamw_update(params, grads, opt2,
                             AdamWConfig(use_kernel=True), 2e-3)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5,
                                                         atol=1e-6), p1, p2)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5,
                                                         atol=1e-6),
                 o1["m"], o2["m"])


def test_global_norm_clip():
    g = {"x": jnp.full((4,), 3.0)}   # norm 6
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 6.0, rtol=1e-6)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["x"])), 1.0, rtol=1e-5)
    # below threshold -> unchanged
    unclipped, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(unclipped["x"], g["x"])


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                              total_steps=100))
    lr10 = float(warmup_cosine(10, peak_lr=1e-3, min_lr=1e-4, warmup_steps=10,
                               total_steps=100))
    lr100 = float(warmup_cosine(100, peak_lr=1e-3, min_lr=1e-4,
                                warmup_steps=10, total_steps=100))
    assert lr0 == 0.0
    np.testing.assert_allclose(lr10, 1e-3, rtol=1e-5)
    np.testing.assert_allclose(lr100, 1e-4, rtol=1e-4)
    assert lr10 > float(warmup_cosine(50, peak_lr=1e-3, min_lr=1e-4,
                                      warmup_steps=10, total_steps=100)) > lr100
