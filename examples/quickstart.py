"""Quickstart: pretrain a tiny Llama with the adaptive batch-size schedule.

    PYTHONPATH=src python examples/quickstart.py

Watch the `bsz` column: the norm test (Algorithm 1) grows the global batch
as training progresses — small batches early (cheap, high gradient noise
tolerated), large batches late (efficient, noise must shrink).
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.launch.train import TrainJob, run_training, summarize

job = TrainJob(
    arch="llama3.2-1b",          # smoke-sized variant of the config
    smoke=True,
    schedule="adaptive",          # the paper's contribution
    eta=0.12,                     # gradient-noise tolerance (paper: 0.05-0.3)
    step_impl="accum_norm",       # single-device friendly estimator
    steps=60, seq_len=64,
    base_global_batch=4, max_global_batch=64,
    base_micro_batch=2, max_micro_batch=4, base_accum=2,
    eval_every=20,
)
hist = run_training(job)

print(f"{'step':>5} {'bsz':>5} {'loss':>8} {'T_k':>8}")
for i in range(0, len(hist["step"]), 5):
    print(f"{hist['step'][i]:>5} {hist['global_batch'][i]:>5} "
          f"{hist['loss'][i]:>8.4f} {hist['T'][i]:>8.1f}")
print("\nsummary:", summarize(hist))
