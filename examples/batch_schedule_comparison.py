"""Figure 2 analog: adaptive vs constant vs stagewise batch-size schedules on
the same model/data — training loss, validation loss and the batch-size
trajectory (the paper's key qualitative claims at CPU scale).

    PYTHONPATH=src python examples/batch_schedule_comparison.py [--steps N]

Expected outcome (mirrors paper Figure 2 / Table 1):
  * constant-large trains fastest per step but worst val loss;
  * constant-small best val loss but most steps;
  * adaptive starts small and grows, landing near small-batch loss with
    near-large-batch efficiency.
Writes experiments/schedule_comparison.csv.
"""
import argparse, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.launch.train import TrainJob, run_training, summarize

parser = argparse.ArgumentParser()
parser.add_argument("--steps", type=int, default=60,
                    help="sample budget = steps * 64 (every scheme gets the "
                         "same samples, like the paper's Tables 1-3)")
parser.add_argument("--arch", default="microllama-300m")
args = parser.parse_args()

SCHEMES = {
    "adaptive_eta0.1": dict(schedule="adaptive", eta=0.1),
    "adaptive_eta0.2": dict(schedule="adaptive", eta=0.2),
    "constant_4": dict(schedule="constant", base_global_batch=4,
                       max_global_batch=4),
    "constant_64": dict(schedule="constant", base_global_batch=64,
                        max_global_batch=64),
    "stagewise_2.5-2.5-95": dict(schedule="stagewise",
                                 stages=((0.025, 4), (0.025, 16), (0.95, 64))),
}

rows = []
for name, kw in SCHEMES.items():
    base = dict(arch=args.arch, steps=10**9, total_samples=args.steps * 64,
                seq_len=64,
                base_global_batch=4, max_global_batch=64, base_micro_batch=2,
                max_micro_batch=4, base_accum=2, step_impl="accum_norm",
                eval_every=max(args.steps // 3, 1), eval_batches=2)
    base.update(kw)
    hist = run_training(TrainJob(**base))
    s = summarize(hist)
    rows.append((name, s))
    print(f"{name:24s} steps={s['steps']:3d} avg_bsz={s['avg_batch']:6.1f} "
          f"loss={s['best_loss']:.3f} val={s['best_val_loss']:.3f} "
          f"time={s['wall_s']:.0f}s  batch trajectory: "
          f"{hist['global_batch'][0]} -> {hist['global_batch'][-1]}")

os.makedirs("experiments", exist_ok=True)
with open("experiments/schedule_comparison.csv", "w") as f:
    f.write("scheme,steps,avg_bsz,best_loss,best_val_loss,wall_s\n")
    for name, s in rows:
        f.write(f"{name},{s['steps']},{s['avg_batch']:.1f},"
                f"{s['best_loss']:.4f},{s['best_val_loss']:.4f},"
                f"{s['wall_s']:.1f}\n")
print("\nwrote experiments/schedule_comparison.csv")
