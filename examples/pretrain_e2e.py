"""End-to-end driver: pretrain a ~20M-parameter Llama-family model for a few
hundred steps with the adaptive batch schedule, eval + checkpointing — the
full production path (data pipeline -> distributed step -> controller ->
checkpoint) at CPU-tractable scale.  With --full and real hardware the same
driver pretrains microllama-300m exactly as in the paper.

    PYTHONPATH=src python examples/pretrain_e2e.py [--steps 300]
"""
import argparse, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.configs import microllama_300m
from repro.launch.train import TrainJob, run_training, summarize

parser = argparse.ArgumentParser()
parser.add_argument("--steps", type=int, default=150)
parser.add_argument("--full", action="store_true",
                    help="use the real 300M config (needs accelerators)")
parser.add_argument("--eta", type=float, default=0.15)
args = parser.parse_args()

if not args.full:
    # a ~20M-param member of the same family (4 layers, d=512)
    import repro.configs as C
    cfg = microllama_300m.CONFIG.replace(
        name="microllama-20m", num_layers=4, d_model=512, num_heads=8,
        num_kv_heads=8, head_dim=64, d_ff=1408, vocab_size=8192)
    # register it so TrainJob can find it
    mod = type(sys)("repro.configs._e2e")
    mod.CONFIG = cfg
    mod.smoke_config = lambda: cfg
    C._REGISTRY["microllama-20m"] = "_e2e"
    sys.modules["repro.configs._e2e"] = mod
    arch, smoke = "microllama-20m", False
else:
    arch, smoke = "microllama-300m", False

job = TrainJob(
    arch=arch, smoke=smoke, schedule="adaptive", eta=args.eta,
    step_impl="accum_norm", steps=args.steps, seq_len=128,
    base_global_batch=8, max_global_batch=64, base_micro_batch=2,
    max_micro_batch=8, base_accum=2, eval_every=50, eval_batches=4,
    checkpoint_dir="experiments/e2e_ckpt", log_path="experiments/e2e_log.csv",
    peak_lr=6e-4, warmup_frac=0.02,
)
hist = run_training(job)
s = summarize(hist)
print("final:", s)
print(f"batch grew {hist['global_batch'][0]} -> {hist['global_batch'][-1]}; "
      f"checkpoint at experiments/e2e_ckpt, log at experiments/e2e_log.csv")
assert hist["loss"][-1] < hist["loss"][0]
