"""Batched serving demo: KV-cache decode with the GSPMD serve step, including
the long-context ring-buffer mode.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-27b
"""
import argparse, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.launch.serve import run_serving

parser = argparse.ArgumentParser()
parser.add_argument("--arch", default="gemma2-27b")
parser.add_argument("--batch", type=int, default=4)
args = parser.parse_args()

res = run_serving(args.arch, smoke=True, batch=args.batch, prompt_len=24,
                  gen_len=24)
print(f"arch={args.arch} batch={args.batch}")
print(f"prefill {res['prefill_s']:.2f}s | decode {res['decode_s']:.2f}s "
      f"({res['decode_tok_per_s']:.1f} tok/s)")
for i, row in enumerate(res["tokens"][:2]):
    print(f"request {i}: {row[:12].tolist()} ...")
