"""Critical-batch-size tracking: the norm test as a thresholded
gradient-noise-scale controller (paper §5.4's conjecture, empirically).

Runs an adaptive job, tracks McCandlish's B_simple from the SAME statistics
the norm test computes, and shows the batch trajectory hugging B_simple/eta^2
until the max-batch clamp.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python examples/gns_tracking.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.gns import GNSTracker, gns_from_norm_test, variance_groups
from repro.launch.train import TrainJob, run_training

ETA = 0.15
job = TrainJob(arch="llama3.2-1b", schedule="adaptive", eta=ETA,
               step_impl="accum_norm", steps=50, seq_len=64,
               base_global_batch=4, max_global_batch=256,
               base_micro_batch=2, max_micro_batch=4, base_accum=2,
               eval_every=0)
hist = run_training(job)

workers = hist["workers"]
tracker = GNSTracker(alpha=0.8)
print(f"{'step':>5} {'batch':>6} {'T_k':>9} {'B_simple':>10} {'B/eta^2':>10}")
for i, step in enumerate(hist["step"]):
    b = hist["global_batch"][i]
    # var_l1 arrives on the J scale for both step impls; the GROUP count for
    # the two-scale estimator comes from the recorded per-step plan
    # (M·J groups for ACCUM-NORM), not a hardcoded constant
    groups = variance_groups(job.step_impl, workers,
                             hist["accum_steps"][i])
    est = gns_from_norm_test(hist["var_l1"][i], hist["grad_sqnorm"][i], b,
                             workers)
    tracker = tracker.update(hist["var_l1"][i], hist["grad_sqnorm"][i], b,
                             workers, groups=groups)
    if i % 5 == 0:
        print(f"{step:>5} {b:>6} {hist['T'][i]:>9.1f} "
              f"{est['b_simple']:>10.1f} {est['b_simple']/ETA**2:>10.1f}")
print("\nAlgorithm 1 grows b_k toward T_k = B-related quantity / eta^2;"
      "\nthe trajectory saturates once b_k exceeds the noise scale.")
